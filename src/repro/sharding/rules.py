"""Logical-axis sharding rules with divisibility fallback.

Every parameter / activation dimension carries a *logical* axis name
("batch", "heads", "mlp", ...).  A rule table maps logical names to an
ordered list of candidate mesh axes; the first candidate whose size divides
the dimension (and is not already taken by another dim of the same tensor)
wins, otherwise the dim is replicated.  This is the t5x/MaxText pattern and
is what lets one model definition serve the (16,16) single-pod mesh, the
(2,16,16) multi-pod mesh, CPU smoke tests (1 device) and elastic re-meshes
without edits.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import mesh_and_manual

# ---------------------------------------------------------------------------
# Rule tables
# ---------------------------------------------------------------------------

# Training rules.  Order within each entry = preference order.  A tuple
# entry like ("pod", "data") means "shard over the product of these axes"
# (all must exist in the mesh; divisibility checked on the product).
#
# "embed" is the *parameter* d_model axis: sharded over "data" for training
# (FSDP/ZeRO-3 weight sharding — XLA inserts the per-layer all-gather),
# replicated for serving (decode is memory-bound; re-gathering weights
# every step would swamp ICI).  "d_model" is the *activation* embedding
# axis: always replicated on "model" (Megatron-style TP).
TRAIN_RULES: dict[str, tuple[Any, ...]] = {
    # activations / data
    "batch": (("pod", "data"), ("data",), ("pod",)),
    "seq": (),                      # replicated by default (activations)
    # sequence-sharded residual stream between layers (Megatron-SP):
    # off by default; enabled per-run (RunConfig.seq_shard) — shrinks the
    # remat stash by the model-parallel degree at the cost of AG/RS
    # around each mixer (hillclimb A, dsv3 memory iteration)
    "seq_res": (),
    "kv_seq": (("model",),),        # decode KV cache sequence dim
    "kv_seq_long": (("data", "model"), ("model",),),  # batch-1 long decode
    "d_model": (),                  # Megatron: activations replicated on model
    # parameters
    "embed": (("data",),),          # FSDP weight shard (train)
    "heads": (("model",),),
    "kv_heads": (("model",),),      # falls back to replicate when kv<model
    "mlp": (("model",),),           # FFN hidden
    "vocab": (("model",),),
    "experts": (("model",),),
    # EP layout (hillclimb A): experts over "data" (classic MoE a2a:
    # token-major -> expert-major over the same shards), contraction dim
    # of the expert matmuls over "model".  Realized with an explicit
    # shard_map (models/moe.apply_moe_ep) after two GSPMD-constraint
    # formulations were refuted — the partitioner lowered the reshard as
    # replicate/all-gather instead of all-to-all (EXPERIMENTS.md §Perf).
    "experts_ep": (("data",), ("model",)),
    "ep_embed": (("model",),),
    "expert_cap": (),
    "layers": (),                   # stacked-scan leading dim
    "ssm_inner": (("model",),),     # mamba d_inner
    "ssm_heads": (("model",),),
    "ssm_state": (),
    "conv_w": (),
    "kv_lora": (),                  # MLA latent dim (small; replicated)
    "q_lora": (),
    "rope": (),
    "head_dim": (),
    "frames": (),                   # audio encoder stub frames
    # optimizer-state extra sharding (ZeRO-1): tried on top of param rules
    "zero1": (("data",),),
}

# Serving rules: weights resident (no FSDP gather); giant MoE expert banks
# spread EP over (pod, data) with TP on the expert hidden dim.
SERVE_RULES: dict[str, tuple[Any, ...]] = {
    **TRAIN_RULES,
    "embed": (),
    "experts": (("pod", "data"), ("data",), ("model",)),
}

DEFAULT_RULES = TRAIN_RULES


def make_rules(mesh: Mesh, phase: str = "train",
               flat_dp: bool = False) -> "AxisRules":
    """flat_dp: treat "model" as a second data axis — for archs whose
    head count does not divide the model axis (whisper: 20 heads vs 16)
    where tensor parallelism would otherwise replicate the attention
    compute on every model rank (hillclimb B)."""
    table = dict(TRAIN_RULES if phase == "train" else SERVE_RULES)
    if flat_dp:
        table["batch"] = (
            ("pod", "data", "model"), ("pod", "data"), ("data", "model"),
            ("data",),
        )
        table["heads"] = ()
        table["kv_heads"] = ()
        table["mlp"] = ()
        table["ssm_inner"] = ()
        table["ssm_heads"] = ()
    return AxisRules(mesh, table)


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """A rule table bound to a mesh."""

    mesh: Mesh
    rules: dict[str, tuple[Any, ...]] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_RULES)
    )

    def mesh_axis_size(self, axes: Sequence[str]) -> int:
        n = 1
        for a in axes:
            n *= self.mesh.shape.get(a, 1)
        return n

    def resolve_dim(self, logical: str | None, size: int, taken: set[str]):
        """Pick mesh axes for one dim, honoring divisibility + exclusivity."""
        if logical is None:
            return None
        for cand in self.rules.get(logical, ()):
            axes = (cand,) if isinstance(cand, str) else tuple(cand)
            if any(a in taken for a in axes):
                continue
            if any(a not in self.mesh.shape for a in axes):
                continue
            n = self.mesh_axis_size(axes)
            if n > 1 and size % n == 0:
                taken.update(axes)
                return axes if len(axes) > 1 else axes[0]
            if n == 1:
                continue
        return None

    def spec(self, logical_axes: Sequence[str | None], shape: Sequence[int]) -> P:
        if len(logical_axes) != len(shape):
            raise ValueError(
                f"logical axes {logical_axes} rank != shape {shape} rank"
            )
        taken: set[str] = set()
        parts = [
            self.resolve_dim(name, dim, taken)
            for name, dim in zip(logical_axes, shape)
        ]
        # trim trailing Nones (canonical form)
        while parts and parts[-1] is None:
            parts.pop()
        return P(*parts)

    def sharding(self, logical_axes, shape) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical_axes, shape))

    def zero1_spec(self, logical_axes: Sequence[str | None],
                   shape: Sequence[int]) -> P:
        """Param spec + an extra 'data' split on the largest still-unsharded
        divisible dim (ZeRO-1 optimizer-state sharding)."""
        base = self.spec(logical_axes, shape)
        parts = list(base) + [None] * (len(shape) - len(base))
        taken = {a for p in parts if p for a in ((p,) if isinstance(p, str) else p)}
        if "data" in taken or "data" not in self.mesh.shape:
            return base
        dsize = self.mesh.shape["data"]
        order = sorted(range(len(shape)), key=lambda i: -shape[i])
        for i in order:
            if parts[i] is None and shape[i] % dsize == 0 and shape[i] >= dsize:
                parts[i] = "data"
                break
        while parts and parts[-1] is None:
            parts.pop()
        return P(*parts)

    def zero1_sharding(self, logical_axes, shape) -> NamedSharding:
        return NamedSharding(self.mesh, self.zero1_spec(logical_axes, shape))


# ---------------------------------------------------------------------------
# Thread-local rule context (used by model code for activation constraints)
# ---------------------------------------------------------------------------

_CTX = threading.local()


@contextlib.contextmanager
def axis_rules(rules: AxisRules | None):
    prev = getattr(_CTX, "rules", None)
    _CTX.rules = rules
    try:
        yield
    finally:
        _CTX.rules = prev


def current_rules() -> AxisRules | None:
    return getattr(_CTX, "rules", None)


def shard(x: jax.Array, *logical_axes: str | None) -> jax.Array:
    """Constrain an activation's sharding; no-op outside an axis_rules ctx.

    Inside a shard_map manual region (e.g. the compressed cross-pod step,
    manual over "pod") the constraint is rebuilt on the context's abstract
    mesh with Manual axes dropped — constraining a manual axis is an error
    and those dims are already physically local.
    """
    rules = current_rules()
    if rules is None:
        return x
    spec = rules.spec(logical_axes, x.shape)
    am, manual, constrainable = mesh_and_manual(rules.mesh)
    if not constrainable:
        return x
    if manual:
        parts = []
        for p_ in tuple(spec):
            if p_ is None:
                parts.append(None)
                continue
            axes = (p_,) if isinstance(p_, str) else tuple(p_)
            axes = tuple(a for a in axes if a not in manual)
            parts.append(
                None if not axes else (axes[0] if len(axes) == 1 else axes)
            )
        while parts and parts[-1] is None:
            parts.pop()
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(am, P(*parts))
        )
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))


# ---------------------------------------------------------------------------
# Parameter schema: declare once, materialize many ways
# ---------------------------------------------------------------------------

InitFn = Callable[[jax.Array, tuple[int, ...], Any], jax.Array]


def _fan_in_init(key, shape, dtype, fan_axis=-2, scale=1.0):
    fan_in = shape[fan_axis] if len(shape) >= 2 else shape[-1]
    std = scale / (fan_in ** 0.5)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def _zeros_init(key, shape, dtype):
    del key
    return jnp.zeros(shape, dtype)


def _ones_init(key, shape, dtype):
    del key
    return jnp.ones(shape, dtype)


def _normal_init(std: float):
    def init(key, shape, dtype):
        return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)

    return init


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Metadata-only description of one parameter tensor."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    dtype: Any = jnp.float32
    init: InitFn = _fan_in_init

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes}")

    @property
    def size(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n


def param(shape, axes, dtype=jnp.float32, init: InitFn = _fan_in_init) -> ParamSpec:
    return ParamSpec(tuple(shape), tuple(axes), dtype, init)


def zeros_param(shape, axes, dtype=jnp.float32) -> ParamSpec:
    return ParamSpec(tuple(shape), tuple(axes), dtype, _zeros_init)


def scale_param(shape, axes, dtype=jnp.float32) -> ParamSpec:
    return ParamSpec(tuple(shape), tuple(axes), dtype, _ones_init)


def normal_param(shape, axes, std, dtype=jnp.float32) -> ParamSpec:
    return ParamSpec(tuple(shape), tuple(axes), dtype, _normal_init(std))


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _tree_map_specs(fn, schema):
    return jax.tree.map(fn, schema, is_leaf=is_spec)


def stack_schema(schema, n: int, axis_name: str | None = "layers"):
    """Add a leading stacked-layers dim to every spec in a schema."""

    def stk(s: ParamSpec) -> ParamSpec:
        def init(key, shape, dtype, _inner=s.init):
            keys = jax.random.split(key, shape[0])
            return jax.vmap(lambda k: _inner(k, shape[1:], dtype))(keys)

        return ParamSpec((n,) + s.shape, (axis_name,) + s.axes, s.dtype, init)

    return _tree_map_specs(stk, schema)


def init_params(schema, key: jax.Array):
    """Materialize real parameter values from a schema."""
    leaves, treedef = jax.tree.flatten(schema, is_leaf=is_spec)
    keys = jax.random.split(key, max(len(leaves), 1))
    vals = [s.init(k, s.shape, s.dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(schema):
    """ShapeDtypeStructs for dry-run lowering — no allocation."""
    return _tree_map_specs(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), schema
    )


def param_axes(schema):
    return _tree_map_specs(lambda s: s.axes, schema)


def param_shardings(schema, rules: AxisRules):
    return _tree_map_specs(lambda s: rules.sharding(s.axes, s.shape), schema)


def param_pspecs(schema, rules: AxisRules):
    return _tree_map_specs(lambda s: rules.spec(s.axes, s.shape), schema)


def zero1_shardings(schema, rules: AxisRules):
    return _tree_map_specs(
        lambda s: rules.zero1_sharding(s.axes, s.shape), schema
    )


def zero1_pspecs(schema, rules: AxisRules):
    return _tree_map_specs(lambda s: rules.zero1_spec(s.axes, s.shape), schema)


def count_params(schema) -> int:
    leaves = jax.tree.leaves(schema, is_leaf=is_spec)
    return sum(s.size for s in leaves)


def cast_schema(schema, dtype):
    return _tree_map_specs(
        lambda s: ParamSpec(s.shape, s.axes, dtype, s.init), schema
    )
