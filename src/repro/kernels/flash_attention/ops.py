"""Jit'd wrapper: Pallas flash attention with jnp fallback."""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def attention(q, k, v, *, causal=True, use_pallas=False,
              bq: int = 128, bk: int = 128, interpret: bool = True):
    if use_pallas:
        return flash_attention(
            q, k, v, causal=causal, bq=bq, bk=bk, interpret=interpret
        )
    return attention_ref(q, k, v, causal=causal)


attention_jit = jax.jit(
    attention, static_argnames=("causal", "use_pallas", "bq", "bk",
                                "interpret"),
)
