"""Pure-jnp oracle: causal GQA attention (full softmax)."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(
    q: jnp.ndarray,   # (B, H, S, D)
    k: jnp.ndarray,   # (B, KH, S, D)
    v: jnp.ndarray,   # (B, KH, S, D)
    *,
    causal: bool = True,
) -> jnp.ndarray:
    B, H, S, D = q.shape
    KH = k.shape[1]
    rep = H // KH
    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q, k, preferred_element_type=jnp.float32
    ) * (D ** -0.5)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -1e30)
    p = jnp.exp(s - jnp.max(s, -1, keepdims=True))
    p = p / jnp.sum(p, -1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(q.dtype), v)
