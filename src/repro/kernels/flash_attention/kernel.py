"""Pallas TPU flash attention (blockwise online softmax), causal GQA.

The prefill hot spot: the chunked-attention formulation in
models/attention.py is the portable/sharded path the dry-run lowers;
this kernel is the TPU deployment target for the inner per-shard
computation.

Tiling (per grid step (b, h, iq, jk)):
  q block (BQ, D) VMEM-resident across the jk sweep; k/v blocks (BK, D)
  stream through VMEM; the (BQ, BK) score tile lives in registers/VMEM
  and never reaches HBM — the flash idea.  Running row-max m, row-sum l
  and the output accumulator sit in VMEM scratch that persists across
  the sequential jk grid dimension (TPU grids execute in order).  GQA
  maps kv-head jk-blocks via h // rep in the BlockSpec index maps.
  BQ/BK default 128 — MXU-aligned (multiples of 128 on the contracted
  and lane dims); D is the natural 64/128.
Causal handling: score tiles strictly above the diagonal are skipped via
pl.when (no DMA waste on masked work); the diagonal tile masks
elementwise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  bq: int, bk: int, scale: float, causal: bool):
    iq = pl.program_id(2)
    jk = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(jk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * bq
    k_start = jk * bk
    # causal: skip tiles entirely above the diagonal
    run = (not causal) or (k_start <= q_start + bq - 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                     # (bq, bk)
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(jk == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bq", "bk", "causal", "interpret"),
)
def flash_attention(
    q: jax.Array,    # (B, H, S, D)
    k: jax.Array,    # (B, KH, S, D)
    v: jax.Array,
    *,
    bq: int = 128,
    bk: int = 128,
    causal: bool = True,
    interpret: bool = True,
):
    B, H, S, D = q.shape
    KH = k.shape[1]
    rep = H // KH
    bq = min(bq, S)
    bk = min(bk, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    grid = (B, H, S // bq, S // bk)
    scale = D ** -0.5

    q_spec = pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0))
    kv_spec = pl.BlockSpec(
        (1, 1, bk, D), lambda b, h, i, j: (b, h // rep, j, 0)
    )
    o_spec = pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0))

    # lint: disable=vmem-budget -- O(bq·D) softmax accumulators, not a
    # wavefield capacity design; no analytic formula governs this kernel
    return pl.pallas_call(
        functools.partial(
            _flash_kernel, bq=bq, bk=bk, scale=scale, causal=causal
        ),
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # running row-max m
            pltpu.VMEM((bq,), jnp.float32),       # running row-sum l
            pltpu.VMEM((bq, D), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
