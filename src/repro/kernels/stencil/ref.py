"""Pure-jnp oracle for the FWI wave-equation timestep (kernel ref).

2-D acoustic wave equation, 2nd-order in time, 4th-order in space:

    p_next = 2·p − p_prev + (v·dt)²·∇²p        (+ sponge damping)

4th-order central Laplacian coefficients per axis:
    [-1/12, 4/3, -5/2, 4/3, -1/12] / h²

The sponge multiplies BOTH p_next and p (the damped p becomes the next
step's p_prev), which is why the kernel emits two outputs — one fused
pass over the fields (the memory-bound hot loop of the paper's app).
Boundary cells use zero halo (free-surface-ish); the sponge absorbs
before reflections matter.
"""
from __future__ import annotations

import jax.numpy as jnp

C0 = -5.0 / 2.0
C1 = 4.0 / 3.0
C2 = -1.0 / 12.0


def _shift(p: jnp.ndarray, dz: int, dx: int) -> jnp.ndarray:
    """Shift with zero fill (zero halo at physical boundary)."""
    out = p
    if dz:
        out = jnp.roll(out, dz, axis=-2)
        if dz > 0:
            out = out.at[..., :dz, :].set(0.0)
        else:
            out = out.at[..., dz:, :].set(0.0)
    if dx:
        out = jnp.roll(out, dx, axis=-1)
        if dx > 0:
            out = out.at[..., :, :dx].set(0.0)
        else:
            out = out.at[..., :, dx:].set(0.0)
    return out


def laplacian(p: jnp.ndarray, inv_h2: float = 1.0) -> jnp.ndarray:
    lap = 2.0 * C0 * p
    for d in (1, 2):
        c = C1 if d == 1 else C2
        lap = lap + c * (
            _shift(p, d, 0) + _shift(p, -d, 0)
            + _shift(p, 0, d) + _shift(p, 0, -d)
        )
    return lap * inv_h2


def wave_step_ref(
    p: jnp.ndarray,        # (..., NZ, NX) current pressure
    p_prev: jnp.ndarray,   # (..., NZ, NX)
    v2dt2: jnp.ndarray,    # (NZ, NX) or broadcastable: (v·dt)²/h²
    sponge: jnp.ndarray,   # (NZ, NX) damping taper in [0, 1]
):
    """One timestep.  Returns (p_next, p_damped) both sponge-damped."""
    lap = laplacian(p)
    p_next = (2.0 * p - p_prev + v2dt2 * lap) * sponge
    return p_next, p * sponge
