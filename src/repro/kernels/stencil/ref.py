"""Pure-jnp oracle for the FWI wave-equation timestep (kernel ref).

2-D acoustic wave equation, 2nd-order in time, 4th-order in space:

    p_next = 2·p − p_prev + (v·dt)²·∇²p        (+ sponge damping)

4th-order central Laplacian coefficients per axis:
    [-1/12, 4/3, -5/2, 4/3, -1/12] / h²

The sponge multiplies BOTH p_next and p (the damped p becomes the next
step's p_prev), which is why the kernel emits two outputs — one fused
pass over the fields (the memory-bound hot loop of the paper's app).
Boundary cells use zero halo (free-surface-ish); the sponge absorbs
before reflections matter.

Two Laplacian formulations live here:

* ``laplacian`` — ONE zero-pad then nine static slices.  This is the
  production form: XLA fuses the slice-adds into a single pass, so the
  only extra materialization is the padded copy.
* ``laplacian_roll`` — the original roll-then-mask form (8 rolls + 8
  masked sets per step, each a full-array copy on CPU), kept as the
  benchmark baseline and as an independent oracle.

Both accumulate terms in the SAME order (center, then the d=1 ring
z/x, then the d=2 ring), so they are bit-identical in f32 — the fused
scan engine built on the fast form reproduces seed results exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

C0 = -5.0 / 2.0
C1 = 4.0 / 3.0
C2 = -1.0 / 12.0

_PAD = 2     # stencil reach per axis


def _shift(p: jnp.ndarray, dz: int, dx: int) -> jnp.ndarray:
    """Shift with zero fill (zero halo at physical boundary)."""
    out = p
    if dz:
        out = jnp.roll(out, dz, axis=-2)
        if dz > 0:
            out = out.at[..., :dz, :].set(0.0)
        else:
            out = out.at[..., dz:, :].set(0.0)
    if dx:
        out = jnp.roll(out, dx, axis=-1)
        if dx > 0:
            out = out.at[..., :, :dx].set(0.0)
        else:
            out = out.at[..., :, dx:].set(0.0)
    return out


def laplacian_roll(p: jnp.ndarray, inv_h2: float = 1.0) -> jnp.ndarray:
    """Seed formulation: roll + masked set per shifted term."""
    lap = 2.0 * C0 * p
    for d in (1, 2):
        c = C1 if d == 1 else C2
        lap = lap + c * (
            _shift(p, d, 0) + _shift(p, -d, 0)
            + _shift(p, 0, d) + _shift(p, 0, -d)
        )
    return lap * inv_h2


def laplacian(p: jnp.ndarray, inv_h2: float = 1.0) -> jnp.ndarray:
    """Pad-and-slice formulation; bit-identical to ``laplacian_roll``."""
    nz, nx = p.shape[-2], p.shape[-1]
    widths = [(0, 0)] * (p.ndim - 2) + [(_PAD, _PAD), (_PAD, _PAD)]
    padded = jnp.pad(p, widths)

    def sh(dz: int, dx: int) -> jnp.ndarray:
        # equals _shift(p, dz, dx): padded window offset by (-dz, -dx)
        return padded[..., _PAD - dz: _PAD - dz + nz,
                      _PAD - dx: _PAD - dx + nx]

    lap = 2.0 * C0 * p
    for d in (1, 2):
        c = C1 if d == 1 else C2
        lap = lap + c * (sh(d, 0) + sh(-d, 0) + sh(0, d) + sh(0, -d))
    return lap * inv_h2


def wave_step_ref(
    p: jnp.ndarray,        # (..., NZ, NX) current pressure
    p_prev: jnp.ndarray,   # (..., NZ, NX)
    v2dt2: jnp.ndarray,    # (NZ, NX) or broadcastable: (v·dt)²/h²
    sponge: jnp.ndarray,   # (NZ, NX) damping taper in [0, 1]
):
    """One timestep.  Returns (p_next, p_damped) both sponge-damped."""
    lap = laplacian(p)
    p_next = (2.0 * p - p_prev + v2dt2 * lap) * sponge
    return p_next, p * sponge


def laplacian_of_padded(padded: jnp.ndarray, nz: int, nx: int) -> jnp.ndarray:
    """``laplacian`` reading an ALREADY-padded field (..., NZ+4, NX+4).

    Same nine slices, same accumulation order — bit-identical to
    ``laplacian(padded[..., 2:-2, 2:-2])`` — but without re-materializing
    the padded copy every step.  The k-step fused block keeps the field
    padded across inner steps, so the per-step ``jnp.pad`` of the
    production form disappears (DESIGN.md §13).
    """

    def sh(dz: int, dx: int) -> jnp.ndarray:
        return padded[..., _PAD - dz: _PAD - dz + nz,
                      _PAD - dx: _PAD - dx + nx]

    lap = 2.0 * C0 * sh(0, 0)
    for d in (1, 2):
        c = C1 if d == 1 else C2
        lap = lap + c * (sh(d, 0) + sh(-d, 0) + sh(0, d) + sh(0, -d))
    return lap


def wave_block_ref(
    p: jnp.ndarray,        # (NZ, NX) current pressure
    p_prev: jnp.ndarray,   # (NZ, NX) previous, already sponge-damped
    v2dt2: jnp.ndarray,    # (NZ, NX)
    sponge: jnp.ndarray,   # (NZ, NX)
    src_vals: jnp.ndarray,  # (k,) source amplitude per inner step
    src_z,                 # scalar int source row
    src_x,                 # scalar int source column
    *,
    receiver_row: int = 0,
):
    """k fused timesteps with in-block source injection + receiver rows.

    The pure-XLA mirror of the Pallas ``wave_block`` kernel (k is static,
    read off ``src_vals.shape``).  Two fusions vs the step-at-a-time
    form (DESIGN.md §13):

    * the field stays PADDED across inner steps (one pad on entry, one
      slice on exit) instead of one ``jnp.pad`` materialization per step;
    * the damped previous field is folded into the next step's leapfrog
      expression (``cur * sponge`` fuses into the elementwise update)
      instead of being materialized as a second full-array output every
      step — only the final block boundary writes it.

    Both are pure re-schedulings of the identical ops in identical
    order: the k-step result is BIT-IDENTICAL to k sequential
    ``wave_step_ref`` + injection steps (the contract the equivalence
    tests pin).  Returns (p_k, p_prev_damped_k, traces (k, NX)).
    """
    k = src_vals.shape[0]
    nz, nx = p.shape[-2], p.shape[-1]
    ppad = jnp.pad(p, ((_PAD, _PAD), (_PAD, _PAD)))
    prevd = p_prev
    traces = []
    for j in range(k):
        cur = ppad[_PAD: _PAD + nz, _PAD: _PAD + nx]
        lap = laplacian_of_padded(ppad, nz, nx)
        pn = (2.0 * cur - prevd + v2dt2 * lap) * sponge
        pn = pn.at[src_z, src_x].add(src_vals[j])
        traces.append(
            jax.lax.dynamic_slice_in_dim(pn, receiver_row, 1, axis=0)[0]
        )
        prevd = cur * sponge
        ppad = jax.lax.dynamic_update_slice(ppad, pn, (_PAD, _PAD))
    return (ppad[_PAD: _PAD + nz, _PAD: _PAD + nx], prevd,
            jnp.stack(traces))


def wave_block_shots_ref(
    p: jnp.ndarray,        # (S, NZ, NX) shot batch, current pressure
    p_prev: jnp.ndarray,   # (S, NZ, NX) previous, already sponge-damped
    v2dt2: jnp.ndarray,    # (NZ, NX) shared model field
    sponge: jnp.ndarray,   # (NZ, NX) shared model field
    src_vals: jnp.ndarray,  # (k,) shared or (S, k) per-shot amplitudes
    src_z,                 # (S,) int per-shot source rows
    src_x,                 # (S,) int per-shot source columns
    *,
    receiver_row: int = 0,
):
    """Shot-batched ``wave_block_ref`` — the XLA mirror of the batched
    Pallas kernel, BIT-IDENTICAL to ``vmap``-of-``wave_block_ref``.

    The whole shot batch advances k steps in one padded-field sweep:
    the Laplacian slices, leapfrog and sponge are elementwise over the
    leading shot axis (slicing commutes with the batch, so every shot's
    value stream is the op-for-op vmap lowering), and the per-shot
    source injection scatters to ``(shot, z_s, x_s)`` — one element per
    batch row, so the adds are order-independent and bitwise equal to
    the per-shot ``at[z, x].add``.  This is the dispatch target
    ``ops.wave_block`` uses for 3-D inputs on the XLA path, keeping the
    engine's bitwise contract intact while the model fields are shared
    (DESIGN.md §17).  Returns (p_k, p_prev_damped_k, traces (S, k, NX)).
    """
    ns, nz, nx = p.shape
    k = src_vals.shape[-1]
    sv = jnp.asarray(src_vals, p.dtype)
    if sv.ndim == 1:
        sv = jnp.broadcast_to(sv, (ns, k))
    zi = jnp.broadcast_to(jnp.asarray(src_z, jnp.int32), (ns,))
    xi = jnp.broadcast_to(jnp.asarray(src_x, jnp.int32), (ns,))
    sidx = jnp.arange(ns)
    ppad = jnp.pad(p, ((0, 0), (_PAD, _PAD), (_PAD, _PAD)))
    prevd = p_prev
    traces = []
    for j in range(k):
        cur = ppad[:, _PAD: _PAD + nz, _PAD: _PAD + nx]
        lap = laplacian_of_padded(ppad, nz, nx)
        pn = (2.0 * cur - prevd + v2dt2 * lap) * sponge
        pn = pn.at[sidx, zi, xi].add(sv[:, j])
        traces.append(
            jax.lax.dynamic_slice_in_dim(pn, receiver_row, 1, axis=1)[:, 0]
        )
        prevd = cur * sponge
        ppad = jax.lax.dynamic_update_slice(ppad, pn, (0, _PAD, _PAD))
    return (ppad[:, _PAD: _PAD + nz, _PAD: _PAD + nx], prevd,
            jnp.stack(traces, axis=1))


def wave_block_shots_strips_ref(
    p: jnp.ndarray,        # (S, NZ, NX) shot batch, current pressure
    p_prev: jnp.ndarray,   # (S, NZ, NX) previous, already sponge-damped
    v2dt2: jnp.ndarray,    # (NZ, NX) shared model field
    sponge: jnp.ndarray,   # (NZ, NX) shared model field
    src_vals: jnp.ndarray,  # (k,) shared or (S, k) per-shot amplitudes
    src_z,                 # (S,) int per-shot source rows
    src_x,                 # (S,) int per-shot source columns
    *,
    receiver_row: int = 0,
    bz: int,
):
    """Shot-batched ``wave_block_strips_ref`` — the strip-tiled XLA
    mirror of the batched STREAMED kernel, BIT-IDENTICAL to both
    ``wave_block_shots_ref`` and ``vmap``-of-``wave_block_strips_ref``.

    Windows carry a leading shot axis — (n_strips, S, win, NX) — while
    the model-field windows stay (n_strips, win, NX) and broadcast
    across shots, mirroring the streamed kernel's single model-field
    DMA slot.  Per-(strip, shot) source injection scatters one element
    per pair (order-independent adds), masked to windows that contain
    the shot's source row, exactly as the single-shot strips mirror
    masks its in-window injection (DESIGN.md §17)."""
    ns, nz, nx = p.shape
    k = src_vals.shape[-1]
    assert nz % bz == 0, (nz, bz)
    win = min(bz + 2 * k * _PAD, nz)
    n = nz // bz
    starts = [min(max(i * bz - k * _PAD, 0), nz - win) for i in range(n)]
    offs = [i * bz - starts[i] for i in range(n)]    # strip offset in window
    stidx = jnp.asarray(starts, jnp.int32)
    oidx = jnp.asarray(offs, jnp.int32)
    sv = jnp.asarray(src_vals, p.dtype)
    if sv.ndim == 1:
        sv = jnp.broadcast_to(sv, (ns, k))
    src_zv = jnp.broadcast_to(jnp.asarray(src_z, jnp.int32), (ns,))
    src_xv = jnp.broadcast_to(jnp.asarray(src_x, jnp.int32), (ns,))

    def windows(a):                   # (S, NZ, NX) -> (n, S, win, NX)
        return jax.vmap(
            lambda st: jax.lax.dynamic_slice_in_dim(a, st, win, axis=-2)
        )(stidx)

    prevd = windows(p_prev)
    vw = windows(v2dt2)               # (n, win, NX), shared across shots
    sw = windows(sponge)
    ppad = jnp.pad(windows(p), ((0, 0), (0, 0), (_PAD, _PAD), (_PAD, _PAD)))
    ow = receiver_row // bz                          # receiver-owning strip
    zi = src_zv[None, :] - stidx[:, None]            # (n, S) in-window rows
    inb = (zi >= 0) & (zi < win)
    zidx = jnp.clip(zi, 0, win - 1)
    ii = jnp.broadcast_to(jnp.arange(n)[:, None], (n, ns))
    ss = jnp.broadcast_to(jnp.arange(ns)[None, :], (n, ns))
    xx = jnp.broadcast_to(src_xv[None, :], (n, ns))
    traces = []
    for j in range(k):
        cur = ppad[:, :, _PAD: _PAD + win, _PAD: _PAD + nx]
        lap = laplacian_of_padded(ppad, win, nx)
        pn = (2.0 * cur - prevd + vw[:, None] * lap) * sw[:, None]
        # every window containing a shot's source row injects for that
        # shot; out-of-window pairs add a masked zero on a clipped row
        amt = jnp.where(inb, sv[None, :, j], jnp.zeros((), pn.dtype))
        pn = pn.at[ii, ss, zidx, xx].add(amt)
        traces.append(pn[ow, :, receiver_row - starts[ow], :])
        prevd = cur * sw[:, None]
        ppad = jax.lax.dynamic_update_slice(ppad, pn, (0, 0, _PAD, _PAD))

    def owned(w, off):                # (S, win, nx) -> (S, bz, nx)
        return jax.lax.dynamic_slice_in_dim(w, off, bz, axis=-2)

    p_out = jnp.moveaxis(jax.vmap(owned)(
        ppad[:, :, _PAD: _PAD + win, _PAD: _PAD + nx], oidx
    ), 0, 1).reshape(ns, nz, nx)
    pp_out = jnp.moveaxis(jax.vmap(owned)(prevd, oidx), 0, 1).reshape(
        ns, nz, nx)
    return p_out, pp_out, jnp.stack(traces, axis=1)


def wave_block_strips_ref(
    p: jnp.ndarray,        # (NZ, NX) current pressure
    p_prev: jnp.ndarray,   # (NZ, NX) previous, already sponge-damped
    v2dt2: jnp.ndarray,    # (NZ, NX)
    sponge: jnp.ndarray,   # (NZ, NX)
    src_vals: jnp.ndarray,  # (k,) source amplitude per inner step
    src_z,                 # scalar int source row
    src_x,                 # scalar int source column
    *,
    receiver_row: int = 0,
    bz: int,
):
    """``wave_block_ref`` re-tiled over z-strips — the XLA mirror of the
    STREAMED kernel's schedule, BIT-IDENTICAL to ``wave_block_ref``.

    Each of the nz/bz strips computes its k steps on a
    ``win = bz + 2·k·HALO`` haloed window (start clamped into the field,
    exactly the kernel's trapezoid), vmapped over strips so the working
    set per strip is O(win·NX) regardless of NZ.  Zero-extending a
    window seeds wrong values at interior window edges whose influence
    creeps inward HALO rows per step; the clamp keeps every owned strip
    ≥ k·HALO rows from any interior edge, so after k steps the owned
    rows are untouched by the creep — and since slicing commutes with
    elementwise ops and the Laplacian accumulates in the same order as
    ``laplacian_of_padded`` on the full field, the owned rows are
    bitwise equal to the unstripped reference.  This is the streamed
    path's bit-exactness oracle (DESIGN.md §15): the Pallas streamed
    kernel matches to its documented stencil-reorder `allclose`, this
    mirror matches ``wave_block_ref`` exactly."""
    k = src_vals.shape[0]
    nz, nx = p.shape[-2], p.shape[-1]
    assert nz % bz == 0, (nz, bz)
    win = min(bz + 2 * k * _PAD, nz)
    n = nz // bz
    starts = [min(max(i * bz - k * _PAD, 0), nz - win) for i in range(n)]
    offs = [i * bz - starts[i] for i in range(n)]    # strip offset in window
    sidx = jnp.asarray(starts, jnp.int32)
    oidx = jnp.asarray(offs, jnp.int32)

    def windows(a):
        return jax.vmap(
            lambda s: jax.lax.dynamic_slice_in_dim(a, s, win, axis=0)
        )(sidx)

    prevd = windows(p_prev)
    vw = windows(v2dt2)
    sw = windows(sponge)
    ppad = jnp.pad(windows(p), ((0, 0), (_PAD, _PAD), (_PAD, _PAD)))
    ow = receiver_row // bz                          # receiver-owning strip
    zi = jnp.asarray(src_z, jnp.int32) - sidx        # (n,) in-window src row
    inb = (zi >= 0) & (zi < win)
    zidx = jnp.clip(zi, 0, win - 1)
    traces = []
    for j in range(k):
        cur = ppad[:, _PAD: _PAD + win, _PAD: _PAD + nx]
        lap = laplacian_of_padded(ppad, win, nx)
        pn = (2.0 * cur - prevd + vw * lap) * sw
        # every window containing the source row injects (neighbors need
        # it too — its influence creeps into their owned strip); masked
        # zero-adds land only on dirty halo rows, never owned ones
        amt = jnp.where(inb, src_vals[j], jnp.zeros((), pn.dtype))
        pn = jax.vmap(lambda f, z, a: f.at[z, src_x].add(a))(pn, zidx, amt)
        traces.append(pn[ow, receiver_row - starts[ow], :])
        prevd = cur * sw
        ppad = jax.lax.dynamic_update_slice(ppad, pn, (0, _PAD, _PAD))

    def owned(w, off):                               # (win, nx) -> (bz, nx)
        return jax.lax.dynamic_slice_in_dim(w, off, bz, axis=0)

    p_out = jax.vmap(owned)(
        ppad[:, _PAD: _PAD + win, _PAD: _PAD + nx], oidx
    ).reshape(nz, nx)
    pp_out = jax.vmap(owned)(prevd, oidx).reshape(nz, nx)
    return p_out, pp_out, jnp.stack(traces)
