"""Pallas TPU kernel: fused 4th-order wave-equation timestep.

TPU adaptation of the paper's (CPU/MPI, Eigen-based) FWI hot loop —
re-blocked for the TPU memory hierarchy instead of ported:

* Row-strip tiling: each grid step owns a (BZ, NX) strip resident in
  VMEM.  The ±2-row z-halo comes from neighbor-strip views of the same
  input (three BlockSpecs with clamped index maps) — x-halo needs no
  exchange because strips span the full width, matching the paper's
  striped second-level partitioning that minimizes communication.
* One fused pass: Laplacian + leapfrog update + sponge damping for BOTH
  outputs (p_next, p_damped) — the fields are read once from HBM per
  step, which is the whole battle for a memory-bound stencil.
* f32 compute; (8,128)-aligned strips (BZ multiple of 8, NX multiple of
  128) keep loads/stores VPU-lane aligned.

Physical-boundary strips (first/last) zero their out-of-domain halo
rows via @pl.when, reproducing ref.py's zero-halo convention exactly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

C0 = -5.0 / 2.0
C1 = 4.0 / 3.0
C2 = -1.0 / 12.0
HALO = 2


def _wave_kernel(
    p_c_ref, p_up_ref, p_dn_ref, p_prev_ref, v2dt2_ref, sponge_ref,
    p_next_ref, p_damped_ref,
):
    i = pl.program_id(0)
    n = pl.num_programs(0)
    bz = p_c_ref.shape[0]
    nx = p_c_ref.shape[1]

    center = p_c_ref[...]

    up = p_up_ref[pl.ds(bz - HALO, HALO), :]           # last rows of strip i-1
    dn = p_dn_ref[pl.ds(0, HALO), :]                   # first rows of strip i+1
    zero_h = jnp.zeros((HALO, nx), center.dtype)
    up = jnp.where(i == 0, zero_h, up)                 # physical boundary
    dn = jnp.where(i == n - 1, zero_h, dn)

    ext = jnp.concatenate([up, center, dn], axis=0)    # (bz+4, nx)

    # z-direction stencil from the extended strip
    lap = 2.0 * C0 * center
    lap += C1 * (ext[HALO - 1: HALO - 1 + bz, :]
                 + ext[HALO + 1: HALO + 1 + bz, :])
    lap += C2 * (ext[HALO - 2: HALO - 2 + bz, :]
                 + ext[HALO + 2: HALO + 2 + bz, :])

    # x-direction stencil with zero boundary fill (full width in-strip)
    def shift_x(a, d):
        rolled = jnp.roll(a, d, axis=1)
        idx = jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)
        if d > 0:
            return jnp.where(idx >= d, rolled, 0.0)
        return jnp.where(idx < nx + d, rolled, 0.0)

    lap += C1 * (shift_x(center, 1) + shift_x(center, -1))
    lap += C2 * (shift_x(center, 2) + shift_x(center, -2))

    sponge = sponge_ref[...]
    p_next = (2.0 * center - p_prev_ref[...] + v2dt2_ref[...] * lap) * sponge
    p_next_ref[...] = p_next
    p_damped_ref[...] = center * sponge


@functools.partial(jax.jit, static_argnames=("bz", "interpret"))
def wave_step_pallas(
    p: jax.Array,          # (NZ, NX) f32
    p_prev: jax.Array,
    v2dt2: jax.Array,
    sponge: jax.Array,
    *,
    bz: int = 128,
    interpret: bool = True,
):
    nz, nx = p.shape
    assert nz % bz == 0, (nz, bz)
    grid = (nz // bz,)
    strip = pl.BlockSpec((bz, nx), lambda i: (i, 0))
    up = pl.BlockSpec((bz, nx), lambda i: (jnp.maximum(i - 1, 0), 0))
    dn = pl.BlockSpec(
        (bz, nx), lambda i: (jnp.minimum(i + 1, nz // bz - 1), 0)
    )
    out_shape = [
        jax.ShapeDtypeStruct((nz, nx), p.dtype),
        jax.ShapeDtypeStruct((nz, nx), p.dtype),
    ]
    return pl.pallas_call(
        _wave_kernel,
        grid=grid,
        in_specs=[strip, up, dn, strip, strip, strip],
        out_specs=[strip, strip],
        out_shape=out_shape,
        interpret=interpret,
    )(p, p, p, p_prev, v2dt2, sponge)
