"""Pallas TPU kernel: fused 4th-order wave-equation timestep.

TPU adaptation of the paper's (CPU/MPI, Eigen-based) FWI hot loop —
re-blocked for the TPU memory hierarchy instead of ported:

* Row-strip tiling: each grid step owns a (BZ, NX) strip resident in
  VMEM.  The pressure field is passed ONCE with a whole-array BlockSpec
  whose index map is constant — the pipeline fetches it a single time
  and every grid step slices its strip plus the ±HALO neighbor rows out
  of the resident copy.  (The seed version passed `p` through THREE
  aliased BlockSpecs — center/up/down neighbor views — which costs 3×
  the HBM reads of the field per step; for a memory-bound stencil that
  was most of the budget.)  x-halo needs no exchange because strips span
  the full width, matching the paper's striped second-level partitioning
  that minimizes communication.
* One fused pass: Laplacian + leapfrog update + sponge damping for BOTH
  outputs (p_next, p_damped) — the fields are read once from HBM per
  step, which is the whole battle for a memory-bound stencil.
* f32 compute; (8,128)-aligned strips (BZ multiple of 8, NX multiple of
  128) keep loads/stores VPU-lane aligned.
* `interpret` auto-selects from the backend: compiled on TPU, interpret
  mode elsewhere (the kernel body runs with real Pallas semantics on
  CPU, validating the BlockSpec/halo logic).  `autotune_bz` sweeps strip
  heights and memoizes the fastest — the block-shape knob the ROADMAP's
  "fast as the hardware allows" goal turns.

Physical-boundary strips (first/last) zero their out-of-domain halo
rows, reproducing ref.py's zero-halo convention exactly.

Capacity: the constant-map whole-array spec keeps the full field in
VMEM (NZ·NX·4 B — 1.4 MB for the paper's 600² grid, comfortably under
the ~16 MB/core budget), which hard-caps the resident design at
~1k²-class grids.  Production surveys (≥ 4096² — DESIGN.md §15) run the
STREAMED kernel instead: ``wave_block_stream_pallas`` holds only a
double-buffered pair of (bz + 2·k·HALO, NX) haloed windows in VMEM and
DMAs strip i+1 in from HBM while strip i computes its k-step trapezoid
— ``stream_vmem_bytes`` is O(bz·NX), independent of NZ, so the grid
height is unbounded by VMEM.  ``pick_bz_stream`` sizes the strip under
an explicit budget and ``should_stream`` auto-selects the design per
(shape, budget); the XLA-path mirror of the same tiling is
``ref.py::wave_block_strips_ref`` (bit-exactness oracle).
"""
from __future__ import annotations

import functools
import time
import warnings

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

C0 = -5.0 / 2.0
C1 = 4.0 / 3.0
C2 = -1.0 / 12.0
HALO = 2

#: per-core VMEM working budget the tiling heuristics plan against
#: (TPU cores have ~16 MB; interpret mode has no hard cap but the
#: heuristics still honor it so CPU-validated tilings carry to TPU)
DEFAULT_VMEM_BUDGET = 16 * 1024 * 1024


class StripFallbackWarning(UserWarning):
    """A grid with no usable strip divisor fell back to ONE whole-height
    strip — correct, but the whole field goes VMEM-resident (the tall-
    grid footgun the streamed path refuses outright)."""


def default_interpret() -> bool:
    """Compiled on TPU, interpret mode everywhere else."""
    return jax.default_backend() != "tpu"


def _warn_whole_strip(nz: int, cap: int, who: str) -> int:
    warnings.warn(
        f"{who}: nz={nz} has no usable strip divisor <= cap={cap}; "
        f"falling back to a SINGLE whole-height strip ({nz} rows "
        f"VMEM-resident). Fine for small grids; for tall grids pad nz "
        f"to a composite height or use the streamed kernel "
        f"(wave_block_stream_pallas), which refuses this fallback.",
        StripFallbackWarning,
        stacklevel=3,
    )
    return nz


def pick_bz(nz: int, cap: int = 128) -> int:
    """Largest divisor of nz ≤ cap, preferring (8,128)-aligned strips.

    Never returns a strip shorter than HALO — the kernel's clamped
    neighbor-row slices assume bz ≥ HALO, so a 1-row strip (e.g. prime
    nz > cap) would silently corrupt the stencil; such grids fall back
    to a single whole-height strip (with a ``StripFallbackWarning`` when
    that strip is taller than the cap — the whole field goes resident)."""
    aligned = [b for b in range(8, cap + 1, 8) if nz % b == 0]
    if aligned:
        return max(aligned)
    ok = [b for b in range(HALO, cap + 1) if nz % b == 0]
    if ok:
        return max(ok)
    return _warn_whole_strip(nz, cap, "pick_bz") if nz > cap else nz


def _shift_x(a, d: int, nx: int):
    """x-shift with zero boundary fill (shared by all stencil kernels).

    Operates on the LAST axis so the same helper serves the (win, NX)
    single-shot windows and the (S, win, NX) shot-batched ones."""
    ax = a.ndim - 1
    rolled = jnp.roll(a, d, axis=ax)
    idx = jax.lax.broadcasted_iota(jnp.int32, a.shape, ax)
    if d > 0:
        return jnp.where(idx >= d, rolled, 0.0)
    return jnp.where(idx < nx + d, rolled, 0.0)


def _wave_kernel(
    p_ref, p_prev_ref, v2dt2_ref, sponge_ref, p_next_ref, p_damped_ref,
    *, bz: int,
):
    i = pl.program_id(0)
    n = pl.num_programs(0)
    nz = p_ref.shape[0]
    nx = p_ref.shape[1]
    row0 = i * bz

    # one resident copy of p serves center AND both halo views
    center = p_ref[pl.ds(pl.multiple_of(row0, bz), bz), :]
    up = p_ref[pl.ds(jnp.maximum(row0 - HALO, 0), HALO), :]
    dn = p_ref[pl.ds(jnp.minimum(row0 + bz, nz - HALO), HALO), :]
    zero_h = jnp.zeros((HALO, nx), center.dtype)
    up = jnp.where(i == 0, zero_h, up)                 # physical boundary
    dn = jnp.where(i == n - 1, zero_h, dn)

    ext = jnp.concatenate([up, center, dn], axis=0)    # (bz+4, nx)

    # z-direction stencil from the extended strip
    lap = 2.0 * C0 * center
    lap += C1 * (ext[HALO - 1: HALO - 1 + bz, :]
                 + ext[HALO + 1: HALO + 1 + bz, :])
    lap += C2 * (ext[HALO - 2: HALO - 2 + bz, :]
                 + ext[HALO + 2: HALO + 2 + bz, :])

    # x-direction stencil with zero boundary fill (full width in-strip)
    lap += C1 * (_shift_x(center, 1, nx) + _shift_x(center, -1, nx))
    lap += C2 * (_shift_x(center, 2, nx) + _shift_x(center, -2, nx))

    sponge = sponge_ref[...]
    p_next = (2.0 * center - p_prev_ref[...] + v2dt2_ref[...] * lap) * sponge
    p_next_ref[...] = p_next
    p_damped_ref[...] = center * sponge


@functools.partial(jax.jit, static_argnames=("bz", "interpret"))
def wave_step_pallas(
    p: jax.Array,          # (NZ, NX) f32
    p_prev: jax.Array,
    v2dt2: jax.Array,
    sponge: jax.Array,
    *,
    bz: int | None = None,
    interpret: bool | None = None,
):
    nz, nx = p.shape
    if bz is None:
        bz = pick_bz(nz)
    if interpret is None:
        interpret = default_interpret()
    assert nz % bz == 0, (nz, bz)
    assert bz >= HALO, (bz, HALO)   # clamped halo slices need bz >= HALO
    grid = (nz // bz,)
    whole = pl.BlockSpec((nz, nx), lambda i: (0, 0))   # fetched once
    strip = pl.BlockSpec((bz, nx), lambda i: (i, 0))
    out_shape = [
        jax.ShapeDtypeStruct((nz, nx), p.dtype),
        jax.ShapeDtypeStruct((nz, nx), p.dtype),
    ]
    return pl.pallas_call(
        functools.partial(_wave_kernel, bz=bz),
        grid=grid,
        in_specs=[whole, strip, strip, strip],
        out_specs=[strip, strip],
        out_shape=out_shape,
        interpret=interpret,
    )(p, p_prev, v2dt2, sponge)


def pick_bz_block(nz: int, k: int, cap: int = 128) -> int:
    """Strip height for the k-step ``wave_block`` kernel.

    Largest divisor of nz ≤ cap (preferring 8-aligned strips) whose
    trapezoidal window ``bz + 2·k·HALO`` still fits inside the field;
    grids too short for any multi-strip trapezoid fall back to a single
    whole-height strip (window == field, both edges physical), warning
    via ``StripFallbackWarning`` when the fallback strip exceeds the cap
    (tall grid going whole-field resident — the streamed path raises
    instead, see ``pick_bz_stream``)."""
    pad = 2 * k * HALO
    aligned = [b for b in range(8, cap + 1, 8)
               if nz % b == 0 and b + pad <= nz]
    if aligned:
        return max(aligned)
    ok = [b for b in range(2, cap + 1) if nz % b == 0 and b + pad <= nz]
    if ok:
        return max(ok)
    # no multi-row strip fits (e.g. prime nz): one whole-height strip
    # beats a degenerate 1-row tiling that recomputes the window nz times
    return _warn_whole_strip(nz, cap, "pick_bz_block") if nz > cap else nz


def resident_vmem_bytes(nz: int, nx: int, k: int = 1,
                        bz: int | None = None, s: int = 1) -> int:
    """VMEM footprint of the RESIDENT (whole-array BlockSpec) design:
    ``2·s`` whole (NZ, NX) f32 wavefields plus the TWO shared model
    fields fetched once, the pipeline's double-buffered output strips
    (per shot) and the trace block.  ``s`` is the shot-batch size — the
    model-field term is charged ONCE regardless of ``s`` (DESIGN.md §17);
    ``s=1`` reduces to the classic single-shot accounting."""
    bz = min(bz if bz is not None else 128, nz)
    return 4 * ((2 * s + 2) * nz * nx + 2 * 2 * s * bz * nx + s * k * nx)


def stream_vmem_bytes(nz: int, nx: int, bz: int, k: int, s: int = 1) -> int:
    """VMEM footprint of the STREAMED design: two DMA slots of
    ``2·s + 2`` (win, NX) haloed f32 windows (``2·s`` shot-tiled
    wavefield windows + ONE shared pair of model-field windows), the
    double-buffered output strips, and the trace block — O(s·bz·NX),
    independent of NZ.  ``s=1`` reduces to the classic accounting."""
    win = min(bz + 2 * k * HALO, nz)
    return 4 * (2 * (2 * s + 2) * win * nx + 2 * 2 * s * bz * nx
                + s * k * nx)


def should_stream(nz: int, nx: int, k: int = 1,
                  vmem_budget: int | None = None, s: int = 1) -> bool:
    """True when the whole-array resident design would not fit the VMEM
    budget — the auto-dispatch rule ``ops.wave_block`` applies."""
    budget = vmem_budget if vmem_budget is not None else DEFAULT_VMEM_BUDGET
    return resident_vmem_bytes(nz, nx, k, s=s) > budget


def pick_bz_stream(nz: int, nx: int, k: int, *,
                   vmem_budget: int | None = None, cap: int = 512,
                   s: int = 1) -> int:
    """Strip height for the STREAMED k-step kernel under a VMEM budget.

    Largest 8-aligned divisor of nz ≤ cap whose double-buffered haloed
    windows fit ``vmem_budget`` (falling back to unaligned divisors ≥ 2
    before giving up).  Unlike ``pick_bz_block`` there is NO whole-height
    fallback: a strip that cannot be streamed within the budget raises —
    the silent blow-the-budget path is exactly the footgun the streamed
    design exists to remove.  ``s`` sizes the shot-batched variant's
    windows (``stream_vmem_bytes(..., s=s)``)."""
    budget = vmem_budget if vmem_budget is not None else DEFAULT_VMEM_BUDGET

    def fits(b: int) -> bool:
        return (nz % b == 0 and b + 2 * k * HALO <= nz
                and stream_vmem_bytes(nz, nx, b, k, s=s) <= budget)

    aligned = [b for b in range(8, min(cap, nz) + 1, 8) if fits(b)]
    if aligned:
        return max(aligned)
    ok = [b for b in range(2, min(cap, nz) + 1) if fits(b)]
    if ok:
        return max(ok)
    raise ValueError(
        f"no streamable strip height for nz={nz}, nx={nx}, k={k} under "
        f"vmem_budget={budget}: either nz has no divisor whose "
        f"(bz + {2 * k * HALO}, {nx}) double-buffered windows fit the "
        f"budget, or the grid is too short for a k={k} trapezoid. "
        f"Lower k, pad nz to a composite height, or raise the budget."
    )


def pick_k(nz: int, cap: int = 8) -> int:
    """Heuristic fused-block length to pair with ``pick_bz_block``.

    Largest power-of-two ≤ cap whose trapezoid still admits a
    multi-strip tiling of nz; degenerate (short) grids get whatever cap
    allows — a single whole-height strip handles any k."""
    k = cap
    while k > 1 and pick_bz_block(nz, k) == nz and nz > 2 * k * HALO:
        k //= 2
    return max(k, 1)


def _trapezoid_k_steps(
    cur, prevd, vw, sw, srcv_ref, srcp_ref, tr_ref,
    *, start, row0, win: int, nx: int, bz: int, k: int, rrow: int,
):
    """k fused leapfrog steps on one (win, NX) haloed window.

    The shared trapezoid body of BOTH block kernels (resident and
    streamed): per inner step, zero-extend in z, 4th-order Laplacian
    (z-rings from the extension, x-rings via ``_shift_x``), leapfrog +
    sponge, iota-masked source injection, and receiver-row capture into
    ``tr_ref`` for the program owning the receiver strip.  Returns the
    updated (cur, prevd) window pair."""
    zi = srcp_ref[0, 0]
    xi = srcp_ref[0, 1]
    iz = jax.lax.broadcasted_iota(jnp.int32, (win, nx), 0)
    ix = jax.lax.broadcasted_iota(jnp.int32, (win, nx), 1)
    zero_h = jnp.zeros((HALO, nx), cur.dtype)
    own_receiver = (rrow >= row0) & (rrow < row0 + bz)

    for j in range(k):
        ext = jnp.concatenate([zero_h, cur, zero_h], axis=0)
        lap = 2.0 * C0 * cur
        lap += C1 * (ext[HALO - 1: HALO - 1 + win, :]
                     + ext[HALO + 1: HALO + 1 + win, :])
        lap += C2 * (ext[HALO - 2: HALO - 2 + win, :]
                     + ext[HALO + 2: HALO + 2 + win, :])
        lap += C1 * (_shift_x(cur, 1, nx) + _shift_x(cur, -1, nx))
        lap += C2 * (_shift_x(cur, 2, nx) + _shift_x(cur, -2, nx))
        pn = (2.0 * cur - prevd + vw * lap) * sw
        # epilogue: source injection + receiver-row capture, fused
        pn = pn + jnp.where(
            (iz == zi - start) & (ix == xi), srcv_ref[0, j], 0.0
        )

        @pl.when(own_receiver)
        def _capture(pn=pn, j=j):
            tr_ref[j, :] = jax.lax.dynamic_slice_in_dim(
                pn, rrow - start, 1, axis=0
            )[0, :]

        prevd = cur * sw
        cur = pn
    return cur, prevd


def _wave_block_kernel(
    p_ref, pp_ref, v2dt2_ref, sponge_ref, srcv_ref, srcp_ref,
    p_out_ref, pp_out_ref, tr_ref,
    *, bz: int, win: int, k: int, rrow: int,
):
    """k fused timesteps on one z-strip (ghost-zone temporal blocking).

    Each program owns a (bz, NX) strip but computes on a (win, NX)
    window, ``win = bz + 2·k·HALO`` clamped to NZ, sliced out of the
    single VMEM-resident copy of each field.  Every inner step
    zero-extends the window in z: at a physical domain edge that IS the
    boundary condition; at an interior window edge it seeds a wrong
    value whose influence creeps inward HALO rows per step — after k
    steps exactly the owned strip is clean (the window start is clamped
    so the strip sits ≥ k·HALO rows from any interior window edge).
    Source injection, sponge damping and the receiver-row capture run in
    the step epilogue, so k launches and 2k wavefield HBM round-trips
    collapse into one pallas_call (DESIGN.md §13)."""
    i = pl.program_id(0)
    nz = p_ref.shape[0]
    nx = p_ref.shape[1]
    row0 = i * bz
    start = jnp.clip(row0 - k * HALO, 0, nz - win)
    off = row0 - start          # strip offset inside the window

    cur = p_ref[pl.ds(start, win), :]
    prevd = pp_ref[pl.ds(start, win), :]      # already sponge-damped
    vw = v2dt2_ref[pl.ds(start, win), :]
    sw = sponge_ref[pl.ds(start, win), :]
    cur, prevd = _trapezoid_k_steps(
        cur, prevd, vw, sw, srcv_ref, srcp_ref, tr_ref,
        start=start, row0=row0, win=win, nx=nx, bz=bz, k=k, rrow=rrow,
    )

    p_out_ref[...] = jax.lax.dynamic_slice_in_dim(cur, off, bz, axis=0)
    pp_out_ref[...] = jax.lax.dynamic_slice_in_dim(prevd, off, bz, axis=0)


@functools.partial(
    jax.jit, static_argnames=("bz", "receiver_row", "interpret")
)
def wave_block_pallas(
    p: jax.Array,          # (NZ, NX) f32
    p_prev: jax.Array,     # (NZ, NX), already sponge-damped
    v2dt2: jax.Array,
    sponge: jax.Array,
    src_vals: jax.Array,   # (k,) source amplitude per inner step
    src_z,                 # scalar int source row
    src_x,                 # scalar int source column
    *,
    receiver_row: int = 0,
    bz: int | None = None,
    interpret: bool | None = None,
):
    """k fused timesteps in ONE pallas_call (k = src_vals.shape[0]).

    Returns (p_k, p_prev_damped_k, traces (k, NX)).  Matches
    ``wave_block_ref`` to stencil-reorder tolerance (the z/x accumulation
    order differs from the reference — documented `allclose`, not
    bitwise; the pure-XLA block path carries the bitwise contract)."""
    nz, nx = p.shape
    k = int(src_vals.shape[0])
    if bz is None:
        bz = pick_bz_block(nz, k)
    if interpret is None:
        interpret = default_interpret()
    win = min(bz + 2 * k * HALO, nz)
    assert nz % bz == 0, (nz, bz)
    # reject oversized explicit strips: a bz < nz whose trapezoid spills
    # past the field would make every program recompute the WHOLE field
    # (grid-fold redundant work); only the single whole-height strip may
    # clamp the window
    assert bz == nz or bz + 2 * k * HALO <= nz, (nz, bz, k)
    grid = (nz // bz,)
    whole = pl.BlockSpec((nz, nx), lambda i: (0, 0))   # fetched once
    strip = pl.BlockSpec((bz, nx), lambda i: (i, 0))
    srcv = src_vals.reshape(1, k).astype(p.dtype)
    srcp = jnp.stack(
        [jnp.asarray(src_z, jnp.int32), jnp.asarray(src_x, jnp.int32)]
    ).reshape(1, 2)
    out_shape = [
        jax.ShapeDtypeStruct((nz, nx), p.dtype),
        jax.ShapeDtypeStruct((nz, nx), p.dtype),
        jax.ShapeDtypeStruct((k, nx), p.dtype),
    ]
    return pl.pallas_call(
        functools.partial(
            _wave_block_kernel, bz=bz, win=win, k=k,
            rrow=int(receiver_row),
        ),
        grid=grid,
        in_specs=[whole, whole, whole, whole,
                  pl.BlockSpec((1, k), lambda i: (0, 0)),
                  pl.BlockSpec((1, 2), lambda i: (0, 0))],
        out_specs=[strip, strip, pl.BlockSpec((k, nx), lambda i: (0, 0))],
        out_shape=out_shape,
        interpret=interpret,
    )(p, p_prev, v2dt2, sponge, srcv, srcp)


def _wave_block_stream_kernel(
    p_hbm, pp_hbm, v_hbm, s_hbm, srcv_ref, srcp_ref,
    p_out_ref, pp_out_ref, tr_ref, win_buf, sems,
    *, bz: int, win: int, k: int, rrow: int,
):
    """STREAMED k-step trapezoid: manual double-buffered window DMA.

    The four fields stay in HBM (``memory_space=ANY``); each grid step
    owns a (bz, NX) strip and computes on a (win, NX) haloed window that
    it DMAs into one of two VMEM slots.  Grid step i starts the fetch of
    strip i+1's window into the OTHER slot before waiting on its own, so
    the next window flies over this strip's k-step compute — the manual
    analogue of the pipelined-BlockSpec prefetch the resident kernel
    gets for free, without requiring the whole field to fit in VMEM
    (DESIGN.md §15).  Trapezoid math is ``_trapezoid_k_steps``, shared
    with the resident kernel."""
    i = pl.program_id(0)
    n = pl.num_programs(0)
    nz = p_hbm.shape[0]
    nx = p_hbm.shape[1]
    fields = (p_hbm, pp_hbm, v_hbm, s_hbm)

    def win_start(strip):
        return jnp.clip(strip * bz - k * HALO, 0, nz - win)

    def dma(slot, strip):
        start = win_start(strip)
        return [
            pltpu.make_async_copy(
                f.at[pl.ds(start, win), :],
                win_buf.at[slot, fi],
                sems.at[slot, fi],
            )
            for fi, f in enumerate(fields)
        ]

    @pl.when(i == 0)                 # warm-up: fetch our own window
    def _warmup():
        for c in dma(0, 0):
            c.start()

    @pl.when(i + 1 < n)              # prefetch next strip's window
    def _prefetch():
        for c in dma((i + 1) % 2, i + 1):
            c.start()

    slot = i % 2
    for c in dma(slot, i):           # wait for our window to land
        c.wait()

    row0 = i * bz
    start = win_start(i)
    off = row0 - start               # strip offset inside the window
    cur, prevd = _trapezoid_k_steps(
        win_buf[slot, 0], win_buf[slot, 1],
        win_buf[slot, 2], win_buf[slot, 3],
        srcv_ref, srcp_ref, tr_ref,
        start=start, row0=row0, win=win, nx=nx, bz=bz, k=k, rrow=rrow,
    )
    p_out_ref[...] = jax.lax.dynamic_slice_in_dim(cur, off, bz, axis=0)
    pp_out_ref[...] = jax.lax.dynamic_slice_in_dim(prevd, off, bz, axis=0)


@functools.partial(
    jax.jit,
    static_argnames=("receiver_row", "bz", "interpret", "vmem_budget"),
)
def wave_block_stream_pallas(
    p: jax.Array,          # (NZ, NX) f32
    p_prev: jax.Array,     # (NZ, NX), already sponge-damped
    v2dt2: jax.Array,
    sponge: jax.Array,
    src_vals: jax.Array,   # (k,) source amplitude per inner step
    src_z,                 # scalar int source row
    src_x,                 # scalar int source column
    *,
    receiver_row: int = 0,
    bz: int | None = None,
    interpret: bool | None = None,
    vmem_budget: int | None = None,
):
    """k fused timesteps, STREAMED: VMEM holds two haloed windows, not
    the field (k = src_vals.shape[0]).

    The production-scale form of ``wave_block_pallas``: fields live in
    HBM and each grid step double-buffer-DMAs its (bz + 2·k·HALO, NX)
    window while the previous strip computes, so capacity is O(bz·NX)
    — a 4096² grid (256 MB resident) streams in ~8 MB of VMEM.  Strip
    height defaults to ``pick_bz_stream`` (raises rather than fall back
    to a whole-height resident strip).  Returns
    (p_k, p_prev_damped_k, traces (k, NX)); same accuracy contract as
    the resident Pallas kernel (allclose vs ``wave_block_ref``; the
    bitwise strip-tiled oracle is ``ref.wave_block_strips_ref``)."""
    nz, nx = p.shape
    k = int(src_vals.shape[0])
    if interpret is None:
        interpret = default_interpret()
    if bz is None:
        bz = pick_bz_stream(nz, nx, k, vmem_budget=vmem_budget)
    budget = vmem_budget if vmem_budget is not None else DEFAULT_VMEM_BUDGET
    win = bz + 2 * k * HALO
    assert nz % bz == 0, (nz, bz)
    assert win <= nz, (nz, bz, k)    # no whole-height fallback, ever
    assert stream_vmem_bytes(nz, nx, bz, k) <= budget, (nz, nx, bz, k)
    grid = (nz // bz,)
    hbm = pl.BlockSpec(memory_space=pltpu.ANY)
    strip = pl.BlockSpec((bz, nx), lambda i: (i, 0))
    srcv = src_vals.reshape(1, k).astype(p.dtype)
    srcp = jnp.stack(
        [jnp.asarray(src_z, jnp.int32), jnp.asarray(src_x, jnp.int32)]
    ).reshape(1, 2)
    out_shape = [
        jax.ShapeDtypeStruct((nz, nx), p.dtype),
        jax.ShapeDtypeStruct((nz, nx), p.dtype),
        jax.ShapeDtypeStruct((k, nx), p.dtype),
    ]
    kwargs = {}
    if not interpret:
        # enforce the budget at compile time on real TPUs; interpret
        # mode has no VMEM, the assert above carries the contract
        kwargs["compiler_params"] = pltpu.TPUCompilerParams(
            vmem_limit_bytes=budget
        )
    return pl.pallas_call(
        functools.partial(
            _wave_block_stream_kernel, bz=bz, win=win, k=k,
            rrow=int(receiver_row),
        ),
        grid=grid,
        in_specs=[hbm, hbm, hbm, hbm,
                  pl.BlockSpec((1, k), lambda i: (0, 0)),
                  pl.BlockSpec((1, 2), lambda i: (0, 0))],
        out_specs=[strip, strip, pl.BlockSpec((k, nx), lambda i: (0, 0))],
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((2, 4, win, nx), p.dtype),
            pltpu.SemaphoreType.DMA((2, 4)),
        ],
        interpret=interpret,
        **kwargs,
    )(p, p_prev, v2dt2, sponge, srcv, srcp)


def _trapezoid_k_steps_shots(
    cur, prevd, vw, sw, srcv_ref, srcp_ref, tr_ref,
    *, start, row0, win: int, nx: int, bz: int, k: int, rrow: int,
):
    """k fused leapfrog steps on an (S, win, NX) shot-batched window.

    The shot-batched twin of ``_trapezoid_k_steps``, shared by BOTH
    batched block kernels (resident and streamed): identical trapezoid
    math vectorized over the leading shot axis, with the shared model
    windows ``vw``/``sw`` kept 2-D — (win, NX) — and broadcast across
    shots, so the model fields are read once per strip no matter how
    many shots ride the batch (DESIGN.md §17).  Source injection and
    receiver capture are per-shot: ``srcp_ref`` is (S, 2) int32 rows /
    columns, ``srcv_ref`` is (S, k) amplitudes."""
    ns = cur.shape[0]
    zi = srcp_ref[:, 0]                       # (S,) per-shot source row
    xi = srcp_ref[:, 1]                       # (S,) per-shot source col
    iz = jax.lax.broadcasted_iota(jnp.int32, (ns, win, nx), 1)
    ix = jax.lax.broadcasted_iota(jnp.int32, (ns, win, nx), 2)
    zsel = (zi - start)[:, None, None]
    xsel = xi[:, None, None]
    zero_h = jnp.zeros((ns, HALO, nx), cur.dtype)
    own_receiver = (rrow >= row0) & (rrow < row0 + bz)

    for j in range(k):
        ext = jnp.concatenate([zero_h, cur, zero_h], axis=1)
        lap = 2.0 * C0 * cur
        lap += C1 * (ext[:, HALO - 1: HALO - 1 + win, :]
                     + ext[:, HALO + 1: HALO + 1 + win, :])
        lap += C2 * (ext[:, HALO - 2: HALO - 2 + win, :]
                     + ext[:, HALO + 2: HALO + 2 + win, :])
        lap += C1 * (_shift_x(cur, 1, nx) + _shift_x(cur, -1, nx))
        lap += C2 * (_shift_x(cur, 2, nx) + _shift_x(cur, -2, nx))
        pn = (2.0 * cur - prevd + vw * lap) * sw
        # epilogue: per-shot source injection + receiver-row capture
        pn = pn + jnp.where(
            (iz == zsel) & (ix == xsel), srcv_ref[:, j][:, None, None], 0.0
        )

        @pl.when(own_receiver)
        def _capture(pn=pn, j=j):
            tr_ref[:, j, :] = jax.lax.dynamic_slice_in_dim(
                pn, rrow - start, 1, axis=1
            )[:, 0, :]

        prevd = cur * sw
        cur = pn
    return cur, prevd


def _wave_block_shots_kernel(
    p_ref, pp_ref, v2dt2_ref, sponge_ref, srcv_ref, srcp_ref,
    p_out_ref, pp_out_ref, tr_ref,
    *, bz: int, win: int, k: int, rrow: int,
):
    """Shot-batched ``_wave_block_kernel``: each program owns an
    (S, bz, NX) strip and computes the k-step trapezoid on (S, win, NX)
    windows sliced from the resident wavefields, while the model fields
    stay 2-D and are sliced ONCE per strip for all shots."""
    i = pl.program_id(0)
    nz = p_ref.shape[1]
    nx = p_ref.shape[2]
    row0 = i * bz
    start = jnp.clip(row0 - k * HALO, 0, nz - win)
    off = row0 - start          # strip offset inside the window

    cur = p_ref[:, pl.ds(start, win), :]
    prevd = pp_ref[:, pl.ds(start, win), :]   # already sponge-damped
    vw = v2dt2_ref[pl.ds(start, win), :]      # shared across shots
    sw = sponge_ref[pl.ds(start, win), :]
    cur, prevd = _trapezoid_k_steps_shots(
        cur, prevd, vw, sw, srcv_ref, srcp_ref, tr_ref,
        start=start, row0=row0, win=win, nx=nx, bz=bz, k=k, rrow=rrow,
    )

    p_out_ref[...] = jax.lax.dynamic_slice_in_dim(cur, off, bz, axis=1)
    pp_out_ref[...] = jax.lax.dynamic_slice_in_dim(prevd, off, bz, axis=1)


def _norm_src_shots(src_vals, src_z, src_x, ns: int, dtype):
    """Normalize batched source args: (k,)-or-(S, k) amplitudes to
    (S, k), per-shot positions to an (S, 2) int32 block."""
    srcv = jnp.asarray(src_vals, dtype)
    if srcv.ndim == 1:
        srcv = jnp.broadcast_to(srcv, (ns, srcv.shape[0]))
    srcp = jnp.stack(
        [jnp.broadcast_to(jnp.asarray(src_z, jnp.int32), (ns,)),
         jnp.broadcast_to(jnp.asarray(src_x, jnp.int32), (ns,))],
        axis=1,
    )
    return srcv, srcp


@functools.partial(
    jax.jit, static_argnames=("bz", "receiver_row", "interpret")
)
def wave_block_shots_pallas(
    p: jax.Array,          # (S, NZ, NX) f32 shot batch
    p_prev: jax.Array,     # (S, NZ, NX), already sponge-damped
    v2dt2: jax.Array,      # (NZ, NX) shared model field
    sponge: jax.Array,     # (NZ, NX) shared model field
    src_vals: jax.Array,   # (k,) shared or (S, k) per-shot amplitudes
    src_z,                 # (S,) int per-shot source rows
    src_x,                 # (S,) int per-shot source columns
    *,
    receiver_row: int = 0,
    bz: int | None = None,
    interpret: bool | None = None,
):
    """Shot-batched ``wave_block_pallas``: k fused timesteps for ALL S
    shots in ONE pallas_call.

    One grid pass covers the whole batch — the model fields are fetched
    once (not once per shot) and every strip's trapezoid is computed for
    all shots together, so kernel launches and model-field HBM traffic
    are amortized S-fold vs ``vmap``-of-``wave_block_pallas``
    (DESIGN.md §17).  Returns (p_k (S, NZ, NX), p_prev_damped_k,
    traces (S, k, NX)); the S=1 batch is bitwise-equal to the 2-D
    kernel (pinned by tests)."""
    ns, nz, nx = p.shape
    k = int(src_vals.shape[-1])
    if bz is None:
        bz = pick_bz_block(nz, k)
    if interpret is None:
        interpret = default_interpret()
    win = min(bz + 2 * k * HALO, nz)
    assert nz % bz == 0, (nz, bz)
    assert bz == nz or bz + 2 * k * HALO <= nz, (nz, bz, k)
    grid = (nz // bz,)
    whole3 = pl.BlockSpec((ns, nz, nx), lambda i: (0, 0, 0))  # fetched once
    whole2 = pl.BlockSpec((nz, nx), lambda i: (0, 0))         # model fields
    strip3 = pl.BlockSpec((ns, bz, nx), lambda i: (0, i, 0))
    srcv, srcp = _norm_src_shots(src_vals, src_z, src_x, ns, p.dtype)
    out_shape = [
        jax.ShapeDtypeStruct((ns, nz, nx), p.dtype),
        jax.ShapeDtypeStruct((ns, nz, nx), p.dtype),
        jax.ShapeDtypeStruct((ns, k, nx), p.dtype),
    ]
    return pl.pallas_call(
        functools.partial(
            _wave_block_shots_kernel, bz=bz, win=win, k=k,
            rrow=int(receiver_row),
        ),
        grid=grid,
        in_specs=[whole3, whole3, whole2, whole2,
                  pl.BlockSpec((ns, k), lambda i: (0, 0)),
                  pl.BlockSpec((ns, 2), lambda i: (0, 0))],
        out_specs=[strip3, strip3,
                   pl.BlockSpec((ns, k, nx), lambda i: (0, 0, 0))],
        out_shape=out_shape,
        interpret=interpret,
    )(p, p_prev, v2dt2, sponge, srcv, srcp)


def _wave_block_shots_stream_kernel(
    p_hbm, pp_hbm, v_hbm, s_hbm, srcv_ref, srcp_ref,
    p_out_ref, pp_out_ref, tr_ref, fwin_buf, mwin_buf, fsems, msems,
    *, bz: int, win: int, k: int, rrow: int,
):
    """Shot-batched STREAMED trapezoid: double-buffered window DMA with
    a shot-tiled wavefield slot and a SINGLE model-field slot.

    The wavefields stay in HBM as (S, NZ, NX); each grid step DMAs an
    (S, win, NX) window pair into one of two VMEM slots.  The model
    fields get their own (2, 2, win, NX) scratch — one (win, NX) window
    per field per slot, DMA'd ONCE per strip and reused by every shot
    in the batch, which is exactly the traffic the shot batch exists to
    amortize (DESIGN.md §17).  Prefetch discipline is identical to
    ``_wave_block_stream_kernel``: strip i starts strip i+1's fetch
    into the other slot before waiting on its own."""
    i = pl.program_id(0)
    n = pl.num_programs(0)
    nz = p_hbm.shape[1]
    nx = p_hbm.shape[2]

    def win_start(strip):
        return jnp.clip(strip * bz - k * HALO, 0, nz - win)

    def dma(slot, strip):
        start = win_start(strip)
        copies = [
            pltpu.make_async_copy(
                f.at[:, pl.ds(start, win), :],
                fwin_buf.at[slot, fi],
                fsems.at[slot, fi],
            )
            for fi, f in enumerate((p_hbm, pp_hbm))
        ]
        copies += [
            pltpu.make_async_copy(
                f.at[pl.ds(start, win), :],
                mwin_buf.at[slot, fi],
                msems.at[slot, fi],
            )
            for fi, f in enumerate((v_hbm, s_hbm))
        ]
        return copies

    @pl.when(i == 0)                 # warm-up: fetch our own window
    def _warmup():
        for c in dma(0, 0):
            c.start()

    @pl.when(i + 1 < n)              # prefetch next strip's window
    def _prefetch():
        for c in dma((i + 1) % 2, i + 1):
            c.start()

    slot = i % 2
    for c in dma(slot, i):           # wait for our window to land
        c.wait()

    row0 = i * bz
    start = win_start(i)
    off = row0 - start               # strip offset inside the window
    cur, prevd = _trapezoid_k_steps_shots(
        fwin_buf[slot, 0], fwin_buf[slot, 1],
        mwin_buf[slot, 0], mwin_buf[slot, 1],
        srcv_ref, srcp_ref, tr_ref,
        start=start, row0=row0, win=win, nx=nx, bz=bz, k=k, rrow=rrow,
    )
    p_out_ref[...] = jax.lax.dynamic_slice_in_dim(cur, off, bz, axis=1)
    pp_out_ref[...] = jax.lax.dynamic_slice_in_dim(prevd, off, bz, axis=1)


@functools.partial(
    jax.jit,
    static_argnames=("receiver_row", "bz", "interpret", "vmem_budget"),
)
def wave_block_shots_stream_pallas(
    p: jax.Array,          # (S, NZ, NX) f32 shot batch
    p_prev: jax.Array,     # (S, NZ, NX), already sponge-damped
    v2dt2: jax.Array,      # (NZ, NX) shared model field
    sponge: jax.Array,     # (NZ, NX) shared model field
    src_vals: jax.Array,   # (k,) shared or (S, k) per-shot amplitudes
    src_z,                 # (S,) int per-shot source rows
    src_x,                 # (S,) int per-shot source columns
    *,
    receiver_row: int = 0,
    bz: int | None = None,
    interpret: bool | None = None,
    vmem_budget: int | None = None,
):
    """Shot-batched ``wave_block_stream_pallas``: VMEM holds two
    (S, win, NX) wavefield window slots plus ONE shared (win, NX)
    model-field slot pair — capacity O(s·bz·NX), independent of NZ.

    Strip height defaults to ``pick_bz_stream(..., s=S)`` (raises
    rather than fall back to a whole-height resident strip — same
    no-fallback contract as the single-shot streamed kernel).  Returns
    (p_k, p_prev_damped_k, traces (S, k, NX))."""
    ns, nz, nx = p.shape
    k = int(src_vals.shape[-1])
    if interpret is None:
        interpret = default_interpret()
    if bz is None:
        bz = pick_bz_stream(nz, nx, k, vmem_budget=vmem_budget, s=ns)
    budget = vmem_budget if vmem_budget is not None else DEFAULT_VMEM_BUDGET
    win = bz + 2 * k * HALO
    assert nz % bz == 0, (nz, bz)
    assert win <= nz, (nz, bz, k)    # no whole-height fallback, ever
    assert stream_vmem_bytes(nz, nx, bz, k, s=ns) <= budget, \
        (nz, nx, bz, k, ns)
    grid = (nz // bz,)
    hbm = pl.BlockSpec(memory_space=pltpu.ANY)
    strip3 = pl.BlockSpec((ns, bz, nx), lambda i: (0, i, 0))
    srcv, srcp = _norm_src_shots(src_vals, src_z, src_x, ns, p.dtype)
    out_shape = [
        jax.ShapeDtypeStruct((ns, nz, nx), p.dtype),
        jax.ShapeDtypeStruct((ns, nz, nx), p.dtype),
        jax.ShapeDtypeStruct((ns, k, nx), p.dtype),
    ]
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.TPUCompilerParams(
            vmem_limit_bytes=budget
        )
    return pl.pallas_call(
        functools.partial(
            _wave_block_shots_stream_kernel, bz=bz, win=win, k=k,
            rrow=int(receiver_row),
        ),
        grid=grid,
        in_specs=[hbm, hbm, hbm, hbm,
                  pl.BlockSpec((ns, k), lambda i: (0, 0)),
                  pl.BlockSpec((ns, 2), lambda i: (0, 0))],
        out_specs=[strip3, strip3,
                   pl.BlockSpec((ns, k, nx), lambda i: (0, 0, 0))],
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((2, 2, ns, win, nx), p.dtype),
            pltpu.VMEM((2, 2, win, nx), p.dtype),
            pltpu.SemaphoreType.DMA((2, 2)),
            pltpu.SemaphoreType.DMA((2, 2)),
        ],
        interpret=interpret,
        **kwargs,
    )(p, p_prev, v2dt2, sponge, srcv, srcp)


def pick_shot_tile(n_shots: int, nz: int, nx: int, k: int, *,
                   bz: int | None = None, stream: bool = False,
                   vmem_budget: int | None = None) -> int:
    """Largest shot-tile ≤ ``n_shots`` whose batched design fits the
    VMEM budget — the default ``shot_tile`` the Pallas dispatch in
    ``ops.wave_block`` uses.

    Resident tiles are sized by ``resident_vmem_bytes(..., s=t)``,
    streamed tiles by the existence of a streamable strip at ``s=t``
    (``pick_bz_stream``).  Only divisors of ``n_shots`` are considered,
    so no tile is ever ragged by default (explicit ``shot_tile`` args
    may still be unaligned — the dispatch handles the remainder tile).
    Always ≥ 1: a single shot that cannot fit resident is the streamed
    path's problem (``should_stream``), not the tile picker's."""
    budget = vmem_budget if vmem_budget is not None else DEFAULT_VMEM_BUDGET

    def fits(t: int) -> bool:
        if stream:
            try:
                pick_bz_stream(nz, nx, k, vmem_budget=budget, s=t)
                return True
            except ValueError:
                return False
        b = bz if bz is not None else pick_bz_block(nz, k)
        return resident_vmem_bytes(nz, nx, k, bz=b, s=t) <= budget

    ok = [t for t in range(1, n_shots + 1) if n_shots % t == 0 and fits(t)]
    return max(ok) if ok else 1


def _tune_backend(backend: str | None) -> str:
    return backend if backend is not None else jax.default_backend()


@functools.lru_cache(maxsize=None)
def _autotune_bz_cached(
    nz: int, nx: int, candidates: tuple[int, ...], repeats: int,
    backend: str,
) -> int:
    cands = [b for b in candidates if nz % b == 0]
    if not cands:
        return pick_bz(nz)
    key = jax.random.key(0)
    p = jax.random.normal(key, (nz, nx), jnp.float32)
    args = (p, p, jnp.full((nz, nx), 0.1, jnp.float32),
            jnp.ones((nz, nx), jnp.float32))
    best_bz, best_t = cands[0], float("inf")
    for b in cands:
        out = wave_step_pallas(*args, bz=b)       # compile
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(repeats):
            out = wave_step_pallas(*args, bz=b)
        jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) / repeats
        if dt < best_t:
            best_bz, best_t = b, dt
    return best_bz


def autotune_bz(
    nz: int, nx: int, candidates: tuple[int, ...] = (8, 16, 32, 64, 128),
    repeats: int = 3, backend: str | None = None,
) -> int:
    """Sweep strip heights on this backend, return the fastest.

    Wall-clock autotune over the real kernel (interpret mode off-TPU, so
    absolute numbers are NOT TPU projections — but the relative ranking
    tracks the tiling trade-off).  Memoized per (shape, candidates,
    backend): an FWISession rebuilt after RESHARD re-reads the cached
    choice instead of re-timing."""
    return _autotune_bz_cached(
        nz, nx, tuple(candidates), repeats, _tune_backend(backend)
    )


@functools.lru_cache(maxsize=None)
def _autotune_bz_k_cached(
    nz: int, nx: int, bz_candidates: tuple[int, ...],
    k_candidates: tuple[int, ...], repeats: int, backend: str,
) -> tuple[int, int]:
    key = jax.random.key(0)
    p = jax.random.normal(key, (nz, nx), jnp.float32)
    v = jnp.full((nz, nx), 0.1, jnp.float32)
    s = jnp.ones((nz, nx), jnp.float32)
    best, best_t = (pick_bz_block(nz, pick_k(nz)), pick_k(nz)), float("inf")
    for k in k_candidates:
        srcv = jnp.zeros((k,), jnp.float32)
        bzs = [b for b in bz_candidates
               if nz % b == 0 and (b + 2 * k * HALO <= nz or b == nz)]
        if not bzs:
            bzs = [pick_bz_block(nz, k)]
        for b in bzs:
            out = wave_block_pallas(p, p, v, s, srcv, 0, 0, bz=b)
            jax.block_until_ready(out)
            t0 = time.perf_counter()
            for _ in range(repeats):
                out = wave_block_pallas(p, p, v, s, srcv, 0, 0, bz=b)
            jax.block_until_ready(out)
            dt = (time.perf_counter() - t0) / (repeats * k)   # per step
            if dt < best_t:
                best, best_t = (b, k), dt
    return best


@functools.lru_cache(maxsize=None)
def _autotune_stream_cached(
    nz: int, nx: int, bz_candidates: tuple[int, ...],
    k_candidates: tuple[int, ...], repeats: int, backend: str,
    budget: int,
) -> tuple[int, int]:
    key = jax.random.key(0)
    p = jax.random.normal(key, (nz, nx), jnp.float32)
    v = jnp.full((nz, nx), 0.1, jnp.float32)
    s = jnp.ones((nz, nx), jnp.float32)
    best, best_t = None, float("inf")
    for k in k_candidates:
        srcv = jnp.zeros((k,), jnp.float32)
        bzs = [b for b in bz_candidates
               if nz % b == 0 and b + 2 * k * HALO <= nz
               and stream_vmem_bytes(nz, nx, b, k) <= budget]
        if not bzs:
            try:
                bzs = [pick_bz_stream(nz, nx, k, vmem_budget=budget)]
            except ValueError:
                continue                      # no streamable strip at this k
        for b in bzs:
            out = wave_block_stream_pallas(
                p, p, v, s, srcv, 0, 0, bz=b, vmem_budget=budget
            )
            jax.block_until_ready(out)
            t0 = time.perf_counter()
            for _ in range(repeats):
                out = wave_block_stream_pallas(
                    p, p, v, s, srcv, 0, 0, bz=b, vmem_budget=budget
                )
            jax.block_until_ready(out)
            dt = (time.perf_counter() - t0) / (repeats * k)   # per step
            if dt < best_t:
                best, best_t = (b, k), dt
    if best is None:
        raise ValueError(
            f"no (bz, k) candidate streams nz={nz}, nx={nx} under "
            f"vmem_budget={budget}"
        )
    return best


@functools.lru_cache(maxsize=None)
def _autotune_shots_cached(
    ns: int, nz: int, nx: int, bz_candidates: tuple[int, ...],
    k_candidates: tuple[int, ...], tile_candidates: tuple[int, ...],
    repeats: int, backend: str, stream: bool, budget: int,
) -> tuple[int, int, int]:
    key = jax.random.key(0)
    p = jax.random.normal(key, (ns, nz, nx), jnp.float32)
    v = jnp.full((nz, nx), 0.1, jnp.float32)
    s = jnp.ones((nz, nx), jnp.float32)
    sz = jnp.full((ns,), nz // 2, jnp.int32)
    sx = jnp.arange(ns, dtype=jnp.int32) % nx
    best, best_t = None, float("inf")
    for k in k_candidates:
        srcv = jnp.zeros((k,), jnp.float32)
        for t in tile_candidates:
            if not 1 <= t <= ns:
                continue
            if stream:
                bzs = [b for b in bz_candidates
                       if nz % b == 0 and b + 2 * k * HALO <= nz
                       and stream_vmem_bytes(nz, nx, b, k, s=t) <= budget]
                if not bzs:
                    try:
                        bzs = [pick_bz_stream(nz, nx, k,
                                              vmem_budget=budget, s=t)]
                    except ValueError:
                        continue          # no streamable strip at (k, t)
            else:
                bzs = [b for b in bz_candidates
                       if nz % b == 0
                       and (b + 2 * k * HALO <= nz or b == nz)
                       and resident_vmem_bytes(nz, nx, k, bz=b,
                                               s=t) <= budget]
                if not bzs:
                    continue              # tile blows the resident budget

            def run(b, t=t, srcv=srcv):
                outs = []
                for lo in range(0, ns, t):
                    hi = min(lo + t, ns)
                    if stream:
                        outs.append(wave_block_shots_stream_pallas(
                            p[lo:hi], p[lo:hi], v, s, srcv,
                            sz[lo:hi], sx[lo:hi], bz=b,
                            vmem_budget=budget,
                        ))
                    else:
                        outs.append(wave_block_shots_pallas(
                            p[lo:hi], p[lo:hi], v, s, srcv,
                            sz[lo:hi], sx[lo:hi], bz=b,
                        ))
                return outs

            for b in bzs:
                jax.block_until_ready(run(b))          # compile
                t0 = time.perf_counter()
                for _ in range(repeats):
                    out = run(b)
                jax.block_until_ready(out)
                # amortized per step per shot
                dt = (time.perf_counter() - t0) / (repeats * k * ns)
                if dt < best_t:
                    best, best_t = (b, k, t), dt
    if best is None:
        raise ValueError(
            f"no (bz, k, shot_tile) candidate fits ns={ns}, nz={nz}, "
            f"nx={nx} under vmem_budget={budget} (stream={stream})"
        )
    return best


def autotune_bz_k(
    nz: int, nx: int,
    bz_candidates: tuple[int, ...] = (8, 16, 24, 32, 40, 64, 120, 128),
    k_candidates: tuple[int, ...] = (1, 2, 4, 8),
    repeats: int = 3, backend: str | None = None,
    *, stream: bool | None = None, vmem_budget: int | None = None,
    n_shots: int | None = None,
    shot_tile_candidates: tuple[int, ...] | None = None,
):
    """Jointly tune (strip height, fused-block length) for ``wave_block``.

    Amortized per-STEP wall clock decides, so longer blocks only win
    when the extra trapezoid compute pays for the saved round trips.
    Memoized per (shape, candidates, backend) in-process — repeated
    ``FWISession`` rebuilds after a RESHARD reuse the cached pair
    instead of re-timing (DESIGN.md §13).

    ``stream`` switches the search to the STREAMED kernel's (strip,
    depth) space, where candidates must also fit ``vmem_budget``
    (``stream_vmem_bytes``); ``stream=None`` auto-selects via
    ``should_stream`` — grids whose resident design would blow the
    budget tune the streamed kernel (DESIGN.md §15).

    ``n_shots`` extends the search to the SHOT-BATCHED engine's
    ``(bz, k, shot_tile)`` space (DESIGN.md §17): candidates sweep the
    tile sizes in ``shot_tile_candidates`` (default: the divisors of
    ``n_shots``), each sized against the s-aware VMEM accounting, and
    the return value becomes a 3-tuple.  Without ``n_shots`` the
    classic 2-tuple ``(bz, k)`` is returned, so existing callers are
    unchanged."""
    budget = vmem_budget if vmem_budget is not None else DEFAULT_VMEM_BUDGET
    if stream is None:
        stream = should_stream(nz, nx, vmem_budget=budget)
    if n_shots is not None:
        if shot_tile_candidates is None:
            shot_tile_candidates = tuple(
                t for t in range(1, n_shots + 1) if n_shots % t == 0
            )
        return _autotune_shots_cached(
            n_shots, nz, nx, tuple(bz_candidates), tuple(k_candidates),
            tuple(shot_tile_candidates), repeats, _tune_backend(backend),
            bool(stream), budget,
        )
    if stream:
        return _autotune_stream_cached(
            nz, nx, tuple(bz_candidates), tuple(k_candidates), repeats,
            _tune_backend(backend), budget,
        )
    return _autotune_bz_k_cached(
        nz, nx, tuple(bz_candidates), tuple(k_candidates), repeats,
        _tune_backend(backend),
    )
