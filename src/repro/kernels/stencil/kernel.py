"""Pallas TPU kernel: fused 4th-order wave-equation timestep.

TPU adaptation of the paper's (CPU/MPI, Eigen-based) FWI hot loop —
re-blocked for the TPU memory hierarchy instead of ported:

* Row-strip tiling: each grid step owns a (BZ, NX) strip resident in
  VMEM.  The pressure field is passed ONCE with a whole-array BlockSpec
  whose index map is constant — the pipeline fetches it a single time
  and every grid step slices its strip plus the ±HALO neighbor rows out
  of the resident copy.  (The seed version passed `p` through THREE
  aliased BlockSpecs — center/up/down neighbor views — which costs 3×
  the HBM reads of the field per step; for a memory-bound stencil that
  was most of the budget.)  x-halo needs no exchange because strips span
  the full width, matching the paper's striped second-level partitioning
  that minimizes communication.
* One fused pass: Laplacian + leapfrog update + sponge damping for BOTH
  outputs (p_next, p_damped) — the fields are read once from HBM per
  step, which is the whole battle for a memory-bound stencil.
* f32 compute; (8,128)-aligned strips (BZ multiple of 8, NX multiple of
  128) keep loads/stores VPU-lane aligned.
* `interpret` auto-selects from the backend: compiled on TPU, interpret
  mode elsewhere (the kernel body runs with real Pallas semantics on
  CPU, validating the BlockSpec/halo logic).  `autotune_bz` sweeps strip
  heights and memoizes the fastest — the block-shape knob the ROADMAP's
  "fast as the hardware allows" goal turns.

Physical-boundary strips (first/last) zero their out-of-domain halo
rows, reproducing ref.py's zero-halo convention exactly.

Capacity note: the constant-map whole-array spec keeps the full field
in VMEM (NZ·NX·4 B — 1.4 MB for the paper's 600² grid, comfortably
under the ~16 MB/core budget).  Grids beyond ~1.8k² would need a
second-level z-split on top.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

C0 = -5.0 / 2.0
C1 = 4.0 / 3.0
C2 = -1.0 / 12.0
HALO = 2


def default_interpret() -> bool:
    """Compiled on TPU, interpret mode everywhere else."""
    return jax.default_backend() != "tpu"


def pick_bz(nz: int, cap: int = 128) -> int:
    """Largest divisor of nz ≤ cap, preferring (8,128)-aligned strips.

    Never returns a strip shorter than HALO — the kernel's clamped
    neighbor-row slices assume bz ≥ HALO, so a 1-row strip (e.g. prime
    nz > cap) would silently corrupt the stencil; such grids fall back
    to a single whole-height strip instead."""
    aligned = [b for b in range(8, cap + 1, 8) if nz % b == 0]
    if aligned:
        return max(aligned)
    ok = [b for b in range(HALO, cap + 1) if nz % b == 0]
    return max(ok) if ok else nz


def _shift_x(a, d: int, nx: int):
    """x-shift with zero boundary fill (shared by both stencil kernels)."""
    rolled = jnp.roll(a, d, axis=1)
    idx = jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)
    if d > 0:
        return jnp.where(idx >= d, rolled, 0.0)
    return jnp.where(idx < nx + d, rolled, 0.0)


def _wave_kernel(
    p_ref, p_prev_ref, v2dt2_ref, sponge_ref, p_next_ref, p_damped_ref,
    *, bz: int,
):
    i = pl.program_id(0)
    n = pl.num_programs(0)
    nz = p_ref.shape[0]
    nx = p_ref.shape[1]
    row0 = i * bz

    # one resident copy of p serves center AND both halo views
    center = p_ref[pl.ds(pl.multiple_of(row0, bz), bz), :]
    up = p_ref[pl.ds(jnp.maximum(row0 - HALO, 0), HALO), :]
    dn = p_ref[pl.ds(jnp.minimum(row0 + bz, nz - HALO), HALO), :]
    zero_h = jnp.zeros((HALO, nx), center.dtype)
    up = jnp.where(i == 0, zero_h, up)                 # physical boundary
    dn = jnp.where(i == n - 1, zero_h, dn)

    ext = jnp.concatenate([up, center, dn], axis=0)    # (bz+4, nx)

    # z-direction stencil from the extended strip
    lap = 2.0 * C0 * center
    lap += C1 * (ext[HALO - 1: HALO - 1 + bz, :]
                 + ext[HALO + 1: HALO + 1 + bz, :])
    lap += C2 * (ext[HALO - 2: HALO - 2 + bz, :]
                 + ext[HALO + 2: HALO + 2 + bz, :])

    # x-direction stencil with zero boundary fill (full width in-strip)
    lap += C1 * (_shift_x(center, 1, nx) + _shift_x(center, -1, nx))
    lap += C2 * (_shift_x(center, 2, nx) + _shift_x(center, -2, nx))

    sponge = sponge_ref[...]
    p_next = (2.0 * center - p_prev_ref[...] + v2dt2_ref[...] * lap) * sponge
    p_next_ref[...] = p_next
    p_damped_ref[...] = center * sponge


@functools.partial(jax.jit, static_argnames=("bz", "interpret"))
def wave_step_pallas(
    p: jax.Array,          # (NZ, NX) f32
    p_prev: jax.Array,
    v2dt2: jax.Array,
    sponge: jax.Array,
    *,
    bz: int | None = None,
    interpret: bool | None = None,
):
    nz, nx = p.shape
    if bz is None:
        bz = pick_bz(nz)
    if interpret is None:
        interpret = default_interpret()
    assert nz % bz == 0, (nz, bz)
    assert bz >= HALO, (bz, HALO)   # clamped halo slices need bz >= HALO
    grid = (nz // bz,)
    whole = pl.BlockSpec((nz, nx), lambda i: (0, 0))   # fetched once
    strip = pl.BlockSpec((bz, nx), lambda i: (i, 0))
    out_shape = [
        jax.ShapeDtypeStruct((nz, nx), p.dtype),
        jax.ShapeDtypeStruct((nz, nx), p.dtype),
    ]
    return pl.pallas_call(
        functools.partial(_wave_kernel, bz=bz),
        grid=grid,
        in_specs=[whole, strip, strip, strip],
        out_specs=[strip, strip],
        out_shape=out_shape,
        interpret=interpret,
    )(p, p_prev, v2dt2, sponge)


def pick_bz_block(nz: int, k: int, cap: int = 128) -> int:
    """Strip height for the k-step ``wave_block`` kernel.

    Largest divisor of nz ≤ cap (preferring 8-aligned strips) whose
    trapezoidal window ``bz + 2·k·HALO`` still fits inside the field;
    grids too short for any multi-strip trapezoid fall back to a single
    whole-height strip (window == field, both edges physical)."""
    pad = 2 * k * HALO
    aligned = [b for b in range(8, cap + 1, 8)
               if nz % b == 0 and b + pad <= nz]
    if aligned:
        return max(aligned)
    ok = [b for b in range(2, cap + 1) if nz % b == 0 and b + pad <= nz]
    # no multi-row strip fits (e.g. prime nz): one whole-height strip
    # beats a degenerate 1-row tiling that recomputes the window nz times
    return max(ok) if ok else nz


def pick_k(nz: int, cap: int = 8) -> int:
    """Heuristic fused-block length to pair with ``pick_bz_block``.

    Largest power-of-two ≤ cap whose trapezoid still admits a
    multi-strip tiling of nz; degenerate (short) grids get whatever cap
    allows — a single whole-height strip handles any k."""
    k = cap
    while k > 1 and pick_bz_block(nz, k) == nz and nz > 2 * k * HALO:
        k //= 2
    return max(k, 1)


def _wave_block_kernel(
    p_ref, pp_ref, v2dt2_ref, sponge_ref, srcv_ref, srcp_ref,
    p_out_ref, pp_out_ref, tr_ref,
    *, bz: int, win: int, k: int, rrow: int,
):
    """k fused timesteps on one z-strip (ghost-zone temporal blocking).

    Each program owns a (bz, NX) strip but computes on a (win, NX)
    window, ``win = bz + 2·k·HALO`` clamped to NZ, sliced out of the
    single VMEM-resident copy of each field.  Every inner step
    zero-extends the window in z: at a physical domain edge that IS the
    boundary condition; at an interior window edge it seeds a wrong
    value whose influence creeps inward HALO rows per step — after k
    steps exactly the owned strip is clean (the window start is clamped
    so the strip sits ≥ k·HALO rows from any interior window edge).
    Source injection, sponge damping and the receiver-row capture run in
    the step epilogue, so k launches and 2k wavefield HBM round-trips
    collapse into one pallas_call (DESIGN.md §13)."""
    i = pl.program_id(0)
    nz = p_ref.shape[0]
    nx = p_ref.shape[1]
    row0 = i * bz
    start = jnp.clip(row0 - k * HALO, 0, nz - win)
    off = row0 - start          # strip offset inside the window

    cur = p_ref[pl.ds(start, win), :]
    prevd = pp_ref[pl.ds(start, win), :]      # already sponge-damped
    vw = v2dt2_ref[pl.ds(start, win), :]
    sw = sponge_ref[pl.ds(start, win), :]
    zi = srcp_ref[0, 0]
    xi = srcp_ref[0, 1]
    iz = jax.lax.broadcasted_iota(jnp.int32, (win, nx), 0)
    ix = jax.lax.broadcasted_iota(jnp.int32, (win, nx), 1)
    zero_h = jnp.zeros((HALO, nx), cur.dtype)
    own_receiver = (rrow >= row0) & (rrow < row0 + bz)

    for j in range(k):
        ext = jnp.concatenate([zero_h, cur, zero_h], axis=0)
        lap = 2.0 * C0 * cur
        lap += C1 * (ext[HALO - 1: HALO - 1 + win, :]
                     + ext[HALO + 1: HALO + 1 + win, :])
        lap += C2 * (ext[HALO - 2: HALO - 2 + win, :]
                     + ext[HALO + 2: HALO + 2 + win, :])
        lap += C1 * (_shift_x(cur, 1, nx) + _shift_x(cur, -1, nx))
        lap += C2 * (_shift_x(cur, 2, nx) + _shift_x(cur, -2, nx))
        pn = (2.0 * cur - prevd + vw * lap) * sw
        # epilogue: source injection + receiver-row capture, fused
        pn = pn + jnp.where(
            (iz == zi - start) & (ix == xi), srcv_ref[0, j], 0.0
        )

        @pl.when(own_receiver)
        def _capture(pn=pn, j=j):
            tr_ref[j, :] = jax.lax.dynamic_slice_in_dim(
                pn, rrow - start, 1, axis=0
            )[0, :]

        prevd = cur * sw
        cur = pn

    p_out_ref[...] = jax.lax.dynamic_slice_in_dim(cur, off, bz, axis=0)
    pp_out_ref[...] = jax.lax.dynamic_slice_in_dim(prevd, off, bz, axis=0)


@functools.partial(
    jax.jit, static_argnames=("bz", "receiver_row", "interpret")
)
def wave_block_pallas(
    p: jax.Array,          # (NZ, NX) f32
    p_prev: jax.Array,     # (NZ, NX), already sponge-damped
    v2dt2: jax.Array,
    sponge: jax.Array,
    src_vals: jax.Array,   # (k,) source amplitude per inner step
    src_z,                 # scalar int source row
    src_x,                 # scalar int source column
    *,
    receiver_row: int = 0,
    bz: int | None = None,
    interpret: bool | None = None,
):
    """k fused timesteps in ONE pallas_call (k = src_vals.shape[0]).

    Returns (p_k, p_prev_damped_k, traces (k, NX)).  Matches
    ``wave_block_ref`` to stencil-reorder tolerance (the z/x accumulation
    order differs from the reference — documented `allclose`, not
    bitwise; the pure-XLA block path carries the bitwise contract)."""
    nz, nx = p.shape
    k = int(src_vals.shape[0])
    if bz is None:
        bz = pick_bz_block(nz, k)
    if interpret is None:
        interpret = default_interpret()
    win = min(bz + 2 * k * HALO, nz)
    assert nz % bz == 0, (nz, bz)
    # reject oversized explicit strips: a bz < nz whose trapezoid spills
    # past the field would make every program recompute the WHOLE field
    # (grid-fold redundant work); only the single whole-height strip may
    # clamp the window
    assert bz == nz or bz + 2 * k * HALO <= nz, (nz, bz, k)
    grid = (nz // bz,)
    whole = pl.BlockSpec((nz, nx), lambda i: (0, 0))   # fetched once
    strip = pl.BlockSpec((bz, nx), lambda i: (i, 0))
    srcv = src_vals.reshape(1, k).astype(p.dtype)
    srcp = jnp.stack(
        [jnp.asarray(src_z, jnp.int32), jnp.asarray(src_x, jnp.int32)]
    ).reshape(1, 2)
    out_shape = [
        jax.ShapeDtypeStruct((nz, nx), p.dtype),
        jax.ShapeDtypeStruct((nz, nx), p.dtype),
        jax.ShapeDtypeStruct((k, nx), p.dtype),
    ]
    return pl.pallas_call(
        functools.partial(
            _wave_block_kernel, bz=bz, win=win, k=k,
            rrow=int(receiver_row),
        ),
        grid=grid,
        in_specs=[whole, whole, whole, whole,
                  pl.BlockSpec((1, k), lambda i: (0, 0)),
                  pl.BlockSpec((1, 2), lambda i: (0, 0))],
        out_specs=[strip, strip, pl.BlockSpec((k, nx), lambda i: (0, 0))],
        out_shape=out_shape,
        interpret=interpret,
    )(p, p_prev, v2dt2, sponge, srcv, srcp)


def _tune_backend(backend: str | None) -> str:
    return backend if backend is not None else jax.default_backend()


@functools.lru_cache(maxsize=None)
def _autotune_bz_cached(
    nz: int, nx: int, candidates: tuple[int, ...], repeats: int,
    backend: str,
) -> int:
    cands = [b for b in candidates if nz % b == 0]
    if not cands:
        return pick_bz(nz)
    key = jax.random.key(0)
    p = jax.random.normal(key, (nz, nx), jnp.float32)
    args = (p, p, jnp.full((nz, nx), 0.1, jnp.float32),
            jnp.ones((nz, nx), jnp.float32))
    best_bz, best_t = cands[0], float("inf")
    for b in cands:
        out = wave_step_pallas(*args, bz=b)       # compile
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(repeats):
            out = wave_step_pallas(*args, bz=b)
        jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) / repeats
        if dt < best_t:
            best_bz, best_t = b, dt
    return best_bz


def autotune_bz(
    nz: int, nx: int, candidates: tuple[int, ...] = (8, 16, 32, 64, 128),
    repeats: int = 3, backend: str | None = None,
) -> int:
    """Sweep strip heights on this backend, return the fastest.

    Wall-clock autotune over the real kernel (interpret mode off-TPU, so
    absolute numbers are NOT TPU projections — but the relative ranking
    tracks the tiling trade-off).  Memoized per (shape, candidates,
    backend): an FWISession rebuilt after RESHARD re-reads the cached
    choice instead of re-timing."""
    return _autotune_bz_cached(
        nz, nx, tuple(candidates), repeats, _tune_backend(backend)
    )


@functools.lru_cache(maxsize=None)
def _autotune_bz_k_cached(
    nz: int, nx: int, bz_candidates: tuple[int, ...],
    k_candidates: tuple[int, ...], repeats: int, backend: str,
) -> tuple[int, int]:
    key = jax.random.key(0)
    p = jax.random.normal(key, (nz, nx), jnp.float32)
    v = jnp.full((nz, nx), 0.1, jnp.float32)
    s = jnp.ones((nz, nx), jnp.float32)
    best, best_t = (pick_bz_block(nz, pick_k(nz)), pick_k(nz)), float("inf")
    for k in k_candidates:
        srcv = jnp.zeros((k,), jnp.float32)
        bzs = [b for b in bz_candidates
               if nz % b == 0 and (b + 2 * k * HALO <= nz or b == nz)]
        if not bzs:
            bzs = [pick_bz_block(nz, k)]
        for b in bzs:
            out = wave_block_pallas(p, p, v, s, srcv, 0, 0, bz=b)
            jax.block_until_ready(out)
            t0 = time.perf_counter()
            for _ in range(repeats):
                out = wave_block_pallas(p, p, v, s, srcv, 0, 0, bz=b)
            jax.block_until_ready(out)
            dt = (time.perf_counter() - t0) / (repeats * k)   # per step
            if dt < best_t:
                best, best_t = (b, k), dt
    return best


def autotune_bz_k(
    nz: int, nx: int,
    bz_candidates: tuple[int, ...] = (8, 16, 24, 32, 40, 64, 120, 128),
    k_candidates: tuple[int, ...] = (1, 2, 4, 8),
    repeats: int = 3, backend: str | None = None,
) -> tuple[int, int]:
    """Jointly tune (strip height, fused-block length) for ``wave_block``.

    Amortized per-STEP wall clock decides, so longer blocks only win
    when the extra trapezoid compute pays for the saved round trips.
    Memoized per (shape, candidates, backend) in-process — repeated
    ``FWISession`` rebuilds after a RESHARD reuse the cached pair
    instead of re-timing (DESIGN.md §13)."""
    return _autotune_bz_k_cached(
        nz, nx, tuple(bz_candidates), tuple(k_candidates), repeats,
        _tune_backend(backend),
    )
