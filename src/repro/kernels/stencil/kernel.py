"""Pallas TPU kernel: fused 4th-order wave-equation timestep.

TPU adaptation of the paper's (CPU/MPI, Eigen-based) FWI hot loop —
re-blocked for the TPU memory hierarchy instead of ported:

* Row-strip tiling: each grid step owns a (BZ, NX) strip resident in
  VMEM.  The pressure field is passed ONCE with a whole-array BlockSpec
  whose index map is constant — the pipeline fetches it a single time
  and every grid step slices its strip plus the ±HALO neighbor rows out
  of the resident copy.  (The seed version passed `p` through THREE
  aliased BlockSpecs — center/up/down neighbor views — which costs 3×
  the HBM reads of the field per step; for a memory-bound stencil that
  was most of the budget.)  x-halo needs no exchange because strips span
  the full width, matching the paper's striped second-level partitioning
  that minimizes communication.
* One fused pass: Laplacian + leapfrog update + sponge damping for BOTH
  outputs (p_next, p_damped) — the fields are read once from HBM per
  step, which is the whole battle for a memory-bound stencil.
* f32 compute; (8,128)-aligned strips (BZ multiple of 8, NX multiple of
  128) keep loads/stores VPU-lane aligned.
* `interpret` auto-selects from the backend: compiled on TPU, interpret
  mode elsewhere (the kernel body runs with real Pallas semantics on
  CPU, validating the BlockSpec/halo logic).  `autotune_bz` sweeps strip
  heights and memoizes the fastest — the block-shape knob the ROADMAP's
  "fast as the hardware allows" goal turns.

Physical-boundary strips (first/last) zero their out-of-domain halo
rows, reproducing ref.py's zero-halo convention exactly.

Capacity note: the constant-map whole-array spec keeps the full field
in VMEM (NZ·NX·4 B — 1.4 MB for the paper's 600² grid, comfortably
under the ~16 MB/core budget).  Grids beyond ~1.8k² would need a
second-level z-split on top.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

C0 = -5.0 / 2.0
C1 = 4.0 / 3.0
C2 = -1.0 / 12.0
HALO = 2


def default_interpret() -> bool:
    """Compiled on TPU, interpret mode everywhere else."""
    return jax.default_backend() != "tpu"


def pick_bz(nz: int, cap: int = 128) -> int:
    """Largest divisor of nz ≤ cap, preferring (8,128)-aligned strips.

    Never returns a strip shorter than HALO — the kernel's clamped
    neighbor-row slices assume bz ≥ HALO, so a 1-row strip (e.g. prime
    nz > cap) would silently corrupt the stencil; such grids fall back
    to a single whole-height strip instead."""
    aligned = [b for b in range(8, cap + 1, 8) if nz % b == 0]
    if aligned:
        return max(aligned)
    ok = [b for b in range(HALO, cap + 1) if nz % b == 0]
    return max(ok) if ok else nz


def _wave_kernel(
    p_ref, p_prev_ref, v2dt2_ref, sponge_ref, p_next_ref, p_damped_ref,
    *, bz: int,
):
    i = pl.program_id(0)
    n = pl.num_programs(0)
    nz = p_ref.shape[0]
    nx = p_ref.shape[1]
    row0 = i * bz

    # one resident copy of p serves center AND both halo views
    center = p_ref[pl.ds(pl.multiple_of(row0, bz), bz), :]
    up = p_ref[pl.ds(jnp.maximum(row0 - HALO, 0), HALO), :]
    dn = p_ref[pl.ds(jnp.minimum(row0 + bz, nz - HALO), HALO), :]
    zero_h = jnp.zeros((HALO, nx), center.dtype)
    up = jnp.where(i == 0, zero_h, up)                 # physical boundary
    dn = jnp.where(i == n - 1, zero_h, dn)

    ext = jnp.concatenate([up, center, dn], axis=0)    # (bz+4, nx)

    # z-direction stencil from the extended strip
    lap = 2.0 * C0 * center
    lap += C1 * (ext[HALO - 1: HALO - 1 + bz, :]
                 + ext[HALO + 1: HALO + 1 + bz, :])
    lap += C2 * (ext[HALO - 2: HALO - 2 + bz, :]
                 + ext[HALO + 2: HALO + 2 + bz, :])

    # x-direction stencil with zero boundary fill (full width in-strip)
    def shift_x(a, d):
        rolled = jnp.roll(a, d, axis=1)
        idx = jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)
        if d > 0:
            return jnp.where(idx >= d, rolled, 0.0)
        return jnp.where(idx < nx + d, rolled, 0.0)

    lap += C1 * (shift_x(center, 1) + shift_x(center, -1))
    lap += C2 * (shift_x(center, 2) + shift_x(center, -2))

    sponge = sponge_ref[...]
    p_next = (2.0 * center - p_prev_ref[...] + v2dt2_ref[...] * lap) * sponge
    p_next_ref[...] = p_next
    p_damped_ref[...] = center * sponge


@functools.partial(jax.jit, static_argnames=("bz", "interpret"))
def wave_step_pallas(
    p: jax.Array,          # (NZ, NX) f32
    p_prev: jax.Array,
    v2dt2: jax.Array,
    sponge: jax.Array,
    *,
    bz: int | None = None,
    interpret: bool | None = None,
):
    nz, nx = p.shape
    if bz is None:
        bz = pick_bz(nz)
    if interpret is None:
        interpret = default_interpret()
    assert nz % bz == 0, (nz, bz)
    assert bz >= HALO, (bz, HALO)   # clamped halo slices need bz >= HALO
    grid = (nz // bz,)
    whole = pl.BlockSpec((nz, nx), lambda i: (0, 0))   # fetched once
    strip = pl.BlockSpec((bz, nx), lambda i: (i, 0))
    out_shape = [
        jax.ShapeDtypeStruct((nz, nx), p.dtype),
        jax.ShapeDtypeStruct((nz, nx), p.dtype),
    ]
    return pl.pallas_call(
        functools.partial(_wave_kernel, bz=bz),
        grid=grid,
        in_specs=[whole, strip, strip, strip],
        out_specs=[strip, strip],
        out_shape=out_shape,
        interpret=interpret,
    )(p, p_prev, v2dt2, sponge)


@functools.lru_cache(maxsize=None)
def autotune_bz(
    nz: int, nx: int, candidates: tuple[int, ...] = (8, 16, 32, 64, 128),
    repeats: int = 3,
) -> int:
    """Sweep strip heights on this backend, return the fastest.

    Wall-clock autotune over the real kernel (interpret mode off-TPU, so
    absolute numbers are NOT TPU projections — but the relative ranking
    tracks the tiling trade-off).  Memoized per (nz, nx, candidates)."""
    cands = [b for b in candidates if nz % b == 0]
    if not cands:
        return pick_bz(nz)
    key = jax.random.key(0)
    p = jax.random.normal(key, (nz, nx), jnp.float32)
    args = (p, p, jnp.full((nz, nx), 0.1, jnp.float32),
            jnp.ones((nz, nx), jnp.float32))
    best_bz, best_t = cands[0], float("inf")
    for b in cands:
        out = wave_step_pallas(*args, bz=b)       # compile
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(repeats):
            out = wave_step_pallas(*args, bz=b)
        jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) / repeats
        if dt < best_t:
            best_bz, best_t = b, dt
    return best_bz
