"""Jit'd wrappers for the wave-step / wave-block kernels with portable
fallbacks.

``wave_step`` advances one timestep; ``wave_block`` advances k fused
timesteps (k = src_vals.shape[0]) with source injection, sponge damping
and receiver-row capture in the step epilogue — one kernel launch and
one wavefield HBM round trip per block instead of per step
(DESIGN.md §13).

use_pallas=True runs the Pallas kernels; ``interpret`` auto-selects
from the backend through the ONE shared helper ``default_interpret``
(compiled on TPU; interpret mode elsewhere, where the kernel body still
executes with real Pallas semantics, validating BlockSpec tiling /
trapezoid logic).  use_pallas=False is the pure-jnp path used on
CPU/GPU: for ``wave_block`` it is the jitted k-step fused body
(``wave_block_ref``), BIT-IDENTICAL to k sequential reference steps;
the Pallas block matches to documented `allclose` tolerance (its z/x
stencil accumulation order differs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.stencil.kernel import (
    autotune_bz,
    autotune_bz_k,
    default_interpret,
    pick_bz,
    pick_bz_block,
    pick_bz_stream,
    pick_k,
    pick_shot_tile,
    should_stream,
    wave_block_pallas,
    wave_block_shots_pallas,
    wave_block_shots_stream_pallas,
    wave_block_stream_pallas,
    wave_step_pallas,
)
from repro.kernels.stencil.ref import (
    wave_block_ref,
    wave_block_shots_ref,
    wave_block_shots_strips_ref,
    wave_block_strips_ref,
    wave_step_ref,
)

__all__ = [
    "wave_step", "wave_step_jit", "wave_step_pallas",
    "wave_block", "wave_block_jit", "wave_block_pallas",
    "wave_block_stream_pallas", "wave_block_strips_ref",
    "wave_block_shots_pallas", "wave_block_shots_stream_pallas",
    "wave_block_shots_ref", "wave_block_shots_strips_ref",
    "autotune_bz", "autotune_bz_k", "default_interpret",
    "pick_bz", "pick_bz_block", "pick_bz_stream", "pick_k",
    "pick_shot_tile", "should_stream",
]


def wave_step(p, p_prev, v2dt2, sponge, *, use_pallas=False,
              bz: int | None = None, interpret: bool | None = None):
    if use_pallas:
        out = wave_step_pallas(
            p, p_prev, v2dt2, sponge, bz=bz, interpret=interpret
        )
        return out[0], out[1]
    return wave_step_ref(p, p_prev, v2dt2, sponge)


wave_step_jit = jax.jit(
    wave_step, static_argnames=("use_pallas", "bz", "interpret")
)


def _wave_block_shots_tiled(
    p, p_prev, v2dt2, sponge, src_vals, src_z, src_x, *,
    receiver_row, use_pallas, bz, interpret, stream, vmem_budget,
    shot_tile,
):
    """Run the shot-batched block kernel over shot tiles of size
    ``shot_tile`` and concatenate — the 3-D dispatch body of
    ``wave_block``.  Per-shot results are independent, so tiling the
    batch is value-preserving (bitwise on the XLA mirror) while keeping
    each pallas_call's VMEM footprint at the tile size, not the full
    batch (DESIGN.md §17)."""
    ns = p.shape[0]
    nz, nx = p.shape[-2], p.shape[-1]
    k = int(src_vals.shape[-1])
    src_z = jnp.asarray(src_z, jnp.int32).reshape(ns)
    src_x = jnp.asarray(src_x, jnp.int32).reshape(ns)
    sv2 = src_vals if getattr(src_vals, "ndim", 1) == 2 else None

    if use_pallas:
        if stream:
            def run(pt, ppt, sv, zt, xt):
                return wave_block_shots_stream_pallas(
                    pt, ppt, v2dt2, sponge, sv, zt, xt,
                    receiver_row=receiver_row, bz=bz, interpret=interpret,
                    vmem_budget=vmem_budget,
                )
        else:
            def run(pt, ppt, sv, zt, xt):
                return wave_block_shots_pallas(
                    pt, ppt, v2dt2, sponge, sv, zt, xt,
                    receiver_row=receiver_row, bz=bz, interpret=interpret,
                )
    elif stream:
        sbz = bz if bz is not None else pick_bz_stream(
            nz, nx, k, vmem_budget=vmem_budget
        )

        def run(pt, ppt, sv, zt, xt):
            return wave_block_shots_strips_ref(
                pt, ppt, v2dt2, sponge, sv, zt, xt,
                receiver_row=receiver_row, bz=sbz,
            )
    else:
        def run(pt, ppt, sv, zt, xt):
            return wave_block_shots_ref(
                pt, ppt, v2dt2, sponge, sv, zt, xt,
                receiver_row=receiver_row,
            )

    if shot_tile >= ns:
        return run(p, p_prev, src_vals, src_z, src_x)
    outs = []
    for lo in range(0, ns, shot_tile):
        hi = min(lo + shot_tile, ns)
        sv = sv2[lo:hi] if sv2 is not None else src_vals
        outs.append(run(p[lo:hi], p_prev[lo:hi], sv,
                        src_z[lo:hi], src_x[lo:hi]))
    return tuple(
        jnp.concatenate([o[i] for o in outs], axis=0) for i in range(3)
    )


def wave_block(p, p_prev, v2dt2, sponge, src_vals, src_z, src_x, *,
               receiver_row: int = 0, use_pallas: bool = False,
               bz: int | None = None, interpret: bool | None = None,
               stream: bool | None = None,
               vmem_budget: int | None = None,
               shot_tile: int | None = None):
    """k fused timesteps; returns (p_k, p_prev_damped_k, traces).

    ``p_prev`` follows the engine convention: it is the already
    sponge-damped previous field, and the returned second output is the
    damped p_{k-1} — the (p, p_prev) carry the scan runners thread.

    2-D wavefields dispatch the classic single-shot kernels.  3-D
    ``(S, NZ, NX)`` wavefields dispatch the SHOT-BATCHED engine
    (DESIGN.md §17): the whole batch advances in one kernel per block,
    sharing the model-field reads across shots; ``src_z``/``src_x`` are
    per-shot ``(S,)`` positions and ``src_vals`` may be ``(k,)`` shared
    or ``(S, k)`` per-shot.  ``shot_tile`` bounds how many shots ride
    one pallas_call (VMEM scales with the tile, not the batch);
    ``None`` auto-picks the largest budget-fitting divisor of S via
    ``pick_shot_tile`` on the Pallas path and the whole batch on the
    XLA path, and unaligned explicit tiles run a smaller remainder tile.

    ``stream`` selects the STREAMED tiling for production-scale grids
    (DESIGN.md §15): ``None`` auto-streams when the whole-array
    resident design would blow ``vmem_budget`` (``should_stream``, per
    shot).  On the Pallas path that is ``wave_block_stream_pallas`` /
    ``wave_block_shots_stream_pallas`` (double-buffered window DMA); on
    the pure-XLA path it is the strip-tiled mirror
    (``wave_block_strips_ref`` / ``wave_block_shots_strips_ref``) that
    stays BIT-IDENTICAL to the unstripped reference while bounding the
    per-strip working set — so both backends share one capacity story."""
    k = int(src_vals.shape[-1])
    nz, nx = p.shape[-2], p.shape[-1]
    if stream is None:
        stream = should_stream(nz, nx, k, vmem_budget=vmem_budget)
    if p.ndim == 3:
        ns = p.shape[0]
        if shot_tile is None:
            shot_tile = pick_shot_tile(
                ns, nz, nx, k, bz=bz, stream=stream,
                vmem_budget=vmem_budget,
            ) if use_pallas else ns
        return _wave_block_shots_tiled(
            p, p_prev, v2dt2, sponge, src_vals, src_z, src_x,
            receiver_row=receiver_row, use_pallas=use_pallas, bz=bz,
            interpret=interpret, stream=stream, vmem_budget=vmem_budget,
            shot_tile=int(shot_tile),
        )
    if use_pallas:
        if stream:
            return wave_block_stream_pallas(
                p, p_prev, v2dt2, sponge, src_vals, src_z, src_x,
                receiver_row=receiver_row, bz=bz, interpret=interpret,
                vmem_budget=vmem_budget,
            )
        return wave_block_pallas(
            p, p_prev, v2dt2, sponge, src_vals, src_z, src_x,
            receiver_row=receiver_row, bz=bz, interpret=interpret,
        )
    if stream:
        sbz = bz if bz is not None else pick_bz_stream(
            nz, nx, k, vmem_budget=vmem_budget
        )
        return wave_block_strips_ref(
            p, p_prev, v2dt2, sponge, src_vals, src_z, src_x,
            receiver_row=receiver_row, bz=sbz,
        )
    return wave_block_ref(
        p, p_prev, v2dt2, sponge, src_vals, src_z, src_x,
        receiver_row=receiver_row,
    )


wave_block_jit = jax.jit(
    wave_block,
    static_argnames=("receiver_row", "use_pallas", "bz", "interpret",
                     "stream", "vmem_budget", "shot_tile"),
)
