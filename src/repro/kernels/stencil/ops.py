"""Jit'd wrapper for the wave-step kernel with a portable fallback.

use_pallas=True runs the Pallas kernel (interpret mode on CPU — the
kernel body executes with real Pallas semantics, validating BlockSpec
tiling/halo logic); use_pallas=False is the pure-jnp oracle used in the
sharded solver (XLA fuses it adequately for the dry-run; the Pallas
path is the TPU deployment target).
"""
from __future__ import annotations

import jax

from repro.kernels.stencil.kernel import wave_step_pallas
from repro.kernels.stencil.ref import wave_step_ref


def wave_step(p, p_prev, v2dt2, sponge, *, use_pallas=False,
              bz: int = 128, interpret: bool = True):
    if use_pallas:
        out = wave_step_pallas(
            p, p_prev, v2dt2, sponge, bz=bz, interpret=interpret
        )
        return out[0], out[1]
    return wave_step_ref(p, p_prev, v2dt2, sponge)


wave_step_jit = jax.jit(
    wave_step, static_argnames=("use_pallas", "bz", "interpret")
)
