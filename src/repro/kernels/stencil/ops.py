"""Jit'd wrappers for the wave-step / wave-block kernels with portable
fallbacks.

``wave_step`` advances one timestep; ``wave_block`` advances k fused
timesteps (k = src_vals.shape[0]) with source injection, sponge damping
and receiver-row capture in the step epilogue — one kernel launch and
one wavefield HBM round trip per block instead of per step
(DESIGN.md §13).

use_pallas=True runs the Pallas kernels; ``interpret`` auto-selects
from the backend through the ONE shared helper ``default_interpret``
(compiled on TPU; interpret mode elsewhere, where the kernel body still
executes with real Pallas semantics, validating BlockSpec tiling /
trapezoid logic).  use_pallas=False is the pure-jnp path used on
CPU/GPU: for ``wave_block`` it is the jitted k-step fused body
(``wave_block_ref``), BIT-IDENTICAL to k sequential reference steps;
the Pallas block matches to documented `allclose` tolerance (its z/x
stencil accumulation order differs).
"""
from __future__ import annotations

import jax

from repro.kernels.stencil.kernel import (
    autotune_bz,
    autotune_bz_k,
    default_interpret,
    pick_bz,
    pick_bz_block,
    pick_bz_stream,
    pick_k,
    should_stream,
    wave_block_pallas,
    wave_block_stream_pallas,
    wave_step_pallas,
)
from repro.kernels.stencil.ref import (
    wave_block_ref,
    wave_block_strips_ref,
    wave_step_ref,
)

__all__ = [
    "wave_step", "wave_step_jit", "wave_step_pallas",
    "wave_block", "wave_block_jit", "wave_block_pallas",
    "wave_block_stream_pallas", "wave_block_strips_ref",
    "autotune_bz", "autotune_bz_k", "default_interpret",
    "pick_bz", "pick_bz_block", "pick_bz_stream", "pick_k",
    "should_stream",
]


def wave_step(p, p_prev, v2dt2, sponge, *, use_pallas=False,
              bz: int | None = None, interpret: bool | None = None):
    if use_pallas:
        out = wave_step_pallas(
            p, p_prev, v2dt2, sponge, bz=bz, interpret=interpret
        )
        return out[0], out[1]
    return wave_step_ref(p, p_prev, v2dt2, sponge)


wave_step_jit = jax.jit(
    wave_step, static_argnames=("use_pallas", "bz", "interpret")
)


def wave_block(p, p_prev, v2dt2, sponge, src_vals, src_z, src_x, *,
               receiver_row: int = 0, use_pallas: bool = False,
               bz: int | None = None, interpret: bool | None = None,
               stream: bool | None = None,
               vmem_budget: int | None = None):
    """k fused timesteps; returns (p_k, p_prev_damped_k, traces (k, NX)).

    ``p_prev`` follows the engine convention: it is the already
    sponge-damped previous field, and the returned second output is the
    damped p_{k-1} — the (p, p_prev) carry the scan runners thread.

    ``stream`` selects the STREAMED tiling for production-scale grids
    (DESIGN.md §15): ``None`` auto-streams when the whole-array
    resident design would blow ``vmem_budget`` (``should_stream``).  On
    the Pallas path that is ``wave_block_stream_pallas`` (double-
    buffered window DMA); on the pure-XLA path it is
    ``wave_block_strips_ref``, the strip-tiled mirror that stays
    BIT-IDENTICAL to ``wave_block_ref`` while bounding the per-strip
    working set — so both backends share one capacity story."""
    k = int(src_vals.shape[0])
    if stream is None:
        nz, nx = p.shape[-2], p.shape[-1]
        stream = should_stream(nz, nx, k, vmem_budget=vmem_budget)
    if use_pallas:
        if stream:
            return wave_block_stream_pallas(
                p, p_prev, v2dt2, sponge, src_vals, src_z, src_x,
                receiver_row=receiver_row, bz=bz, interpret=interpret,
                vmem_budget=vmem_budget,
            )
        return wave_block_pallas(
            p, p_prev, v2dt2, sponge, src_vals, src_z, src_x,
            receiver_row=receiver_row, bz=bz, interpret=interpret,
        )
    if stream:
        nz, nx = p.shape[-2], p.shape[-1]
        sbz = bz if bz is not None else pick_bz_stream(
            nz, nx, k, vmem_budget=vmem_budget
        )
        return wave_block_strips_ref(
            p, p_prev, v2dt2, sponge, src_vals, src_z, src_x,
            receiver_row=receiver_row, bz=sbz,
        )
    return wave_block_ref(
        p, p_prev, v2dt2, sponge, src_vals, src_z, src_x,
        receiver_row=receiver_row,
    )


wave_block_jit = jax.jit(
    wave_block,
    static_argnames=("receiver_row", "use_pallas", "bz", "interpret",
                     "stream", "vmem_budget"),
)
