"""Jit'd wrapper for the wave-step kernel with a portable fallback.

use_pallas=True runs the Pallas kernel; ``interpret`` auto-selects from
the backend (compiled on TPU; interpret mode elsewhere, where the kernel
body still executes with real Pallas semantics, validating BlockSpec
tiling/halo logic).  ``bz=None`` picks an aligned strip height via
``pick_bz`` (or run ``autotune_bz`` for a measured choice).
use_pallas=False is the pure-jnp oracle used on CPU paths (XLA fuses it
adequately; the Pallas path is the TPU deployment target).
"""
from __future__ import annotations

import jax

from repro.kernels.stencil.kernel import (
    autotune_bz,
    default_interpret,
    pick_bz,
    wave_step_pallas,
)
from repro.kernels.stencil.ref import wave_step_ref

__all__ = [
    "wave_step", "wave_step_jit", "wave_step_pallas",
    "autotune_bz", "default_interpret", "pick_bz",
]


def wave_step(p, p_prev, v2dt2, sponge, *, use_pallas=False,
              bz: int | None = None, interpret: bool | None = None):
    if use_pallas:
        out = wave_step_pallas(
            p, p_prev, v2dt2, sponge, bz=bz, interpret=interpret
        )
        return out[0], out[1]
    return wave_step_ref(p, p_prev, v2dt2, sponge)


wave_step_jit = jax.jit(
    wave_step, static_argnames=("use_pallas", "bz", "interpret")
)
