"""Pure-jnp oracle: fused residual-add + RMSNorm."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_residual_ref(x, res, scale, eps: float = 1e-5):
    """Returns (normed(x+res), x+res) — one fused read of x/res."""
    h = x.astype(jnp.float32) + res.astype(jnp.float32)
    ms = jnp.mean(jnp.square(h), axis=-1, keepdims=True)
    normed = h * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)
    return normed.astype(x.dtype), h.astype(x.dtype)
