"""Pallas TPU kernel: fused residual-add + RMSNorm.

Memory-bound fusion: the unfused graph reads x and res, writes h, then
re-reads h for the norm and writes the normed output — 3 reads + 2
writes of (N, d).  Fused: 2 reads + 2 writes, and the reduction runs in
f32 registers.  Rows are tiled (BN, d) with d lane-aligned (multiple of
128 for best layout; any d works functionally).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, res_ref, scale_ref, out_ref, h_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    r = res_ref[...].astype(jnp.float32)
    h = x + r
    ms = jnp.mean(jnp.square(h), axis=-1, keepdims=True)
    normed = h * jax.lax.rsqrt(ms + eps) * scale_ref[...].astype(jnp.float32)
    out_ref[...] = normed.astype(out_ref.dtype)
    h_ref[...] = h.astype(h_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bn", "eps", "interpret"))
def rmsnorm_residual_pallas(
    x: jax.Array,       # (N, d)
    res: jax.Array,     # (N, d)
    scale: jax.Array,   # (d,)
    *,
    bn: int = 256,
    eps: float = 1e-5,
    interpret: bool = True,
):
    N, d = x.shape
    bn = min(bn, N)
    assert N % bn == 0, (N, bn)
    grid = (N // bn,)
    row = pl.BlockSpec((bn, d), lambda i: (i, 0))
    vec = pl.BlockSpec((d,), lambda i: (0,))
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[row, row, vec],
        out_specs=[row, row],
        out_shape=[
            jax.ShapeDtypeStruct((N, d), x.dtype),
            jax.ShapeDtypeStruct((N, d), x.dtype),
        ],
        interpret=interpret,
    )(x, res, scale)
