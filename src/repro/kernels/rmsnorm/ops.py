"""Jit'd wrapper for fused residual+RMSNorm."""
from __future__ import annotations

import jax

from repro.kernels.rmsnorm.kernel import rmsnorm_residual_pallas
from repro.kernels.rmsnorm.ref import rmsnorm_residual_ref


def rmsnorm_residual(x, res, scale, *, eps: float = 1e-5,
                     use_pallas=False, bn: int = 256,
                     interpret: bool = True):
    if use_pallas:
        out = rmsnorm_residual_pallas(
            x, res, scale, bn=bn, eps=eps, interpret=interpret
        )
        return out[0], out[1]
    return rmsnorm_residual_ref(x, res, scale, eps)


rmsnorm_residual_jit = jax.jit(
    rmsnorm_residual,
    static_argnames=("eps", "use_pallas", "bn", "interpret"),
)
