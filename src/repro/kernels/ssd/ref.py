"""Pure-jnp oracle for the SSD intra-chunk computation.

Per (batch·chunk, head): given xdt (Q,P), B (Q,N), C (Q,N) and the
inclusive cumulative decay csum (Q,):
    y_intra[q] = Σ_{t<=q} exp(csum_q - csum_t) · (C_q·B_t) · xdt_t
    state      = Σ_t exp(csum_Q - csum_t) · B_t ⊗ xdt_t      (N, P)
which is the attention-form dual of the selective-scan recurrence
(arXiv:2405.21060 §5) restricted to one chunk.
"""
from __future__ import annotations

import jax.numpy as jnp


def ssd_chunk_ref(xdt, b, c, csum):
    """xdt (..., Q, P); b/c (..., Q, N); csum (..., Q).

    Returns (y_intra (..., Q, P), state (..., N, P))."""
    cb = jnp.einsum("...qn,...tn->...qt", c, b,
                    preferred_element_type=jnp.float32)
    diff = csum[..., :, None] - csum[..., None, :]          # (..., Q, Q)
    Q = xdt.shape[-2]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(mask, jnp.exp(diff), 0.0)
    y = jnp.einsum("...qt,...tp->...qp", (cb * decay).astype(xdt.dtype), xdt)
    to_end = jnp.exp(csum[..., -1:] - csum)                 # (..., Q)
    state = jnp.einsum(
        "...tn,...tp->...np",
        (b * to_end[..., None]).astype(jnp.float32),
        xdt.astype(jnp.float32),
    )
    return y, state
