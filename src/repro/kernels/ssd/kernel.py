"""Pallas TPU kernel: Mamba-2 SSD intra-chunk block.

Grid (batch·chunks, heads); per step the whole (Q, ·) chunk for one head
is VMEM-resident: Q=128/256, P=64, N<=128 gives ~((Q,P)+(Q,N)·2+(Q,Q))·4B
≈ 0.5–1.2 MB — comfortably inside VMEM, with all three matmuls
(C·Bᵀ (Q,N)x(N,Q), (decay∘CB)·xdt (Q,Q)x(Q,P), state Bᵀ·xdt) hitting the
MXU at aligned sizes.  The decay matrix exp(csum_q − csum_t) is built in
registers from the (Q,) cumulative-decay vector — never from HBM.

The inter-chunk recurrence (a cheap (H,N,P) lax.scan over chunks) stays
in XLA (models/mamba2.ssd_chunked): it is O(S/Q) sequential and memory-
light, exactly the part a kernel would not help.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(xdt_ref, b_ref, c_ref, csum_ref, y_ref, state_ref):
    xdt = xdt_ref[0, 0].astype(jnp.float32)      # (Q, P)
    b = b_ref[0, 0].astype(jnp.float32)          # (Q, N)
    c = c_ref[0, 0].astype(jnp.float32)          # (Q, N)
    csum = csum_ref[0, 0].astype(jnp.float32)    # (Q,)
    Q = xdt.shape[0]

    cb = jax.lax.dot_general(
        c, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                             # (Q, Q)
    qpos = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    tpos = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    diff = csum[:, None] - csum[None, :]
    decay = jnp.where(qpos >= tpos, jnp.exp(diff), 0.0)
    y = jax.lax.dot_general(
        cb * decay, xdt, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    to_end = jnp.exp(csum[-1] - csum)             # (Q,)
    state = jax.lax.dot_general(
        b * to_end[:, None], xdt, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                             # (N, P)
    y_ref[0, 0] = y.astype(y_ref.dtype)
    state_ref[0, 0] = state


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk_pallas(
    xdt: jax.Array,    # (BC, H, Q, P)
    b: jax.Array,      # (BC, H, Q, N)
    c: jax.Array,      # (BC, H, Q, N)
    csum: jax.Array,   # (BC, H, Q)
    *,
    interpret: bool = True,
):
    BC, H, Q, P = xdt.shape
    N = b.shape[-1]
    grid = (BC, H)
    spec = lambda *dims: pl.BlockSpec(
        (1, 1) + dims, lambda i, h: (i, h) + (0,) * len(dims)
    )
    return pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[spec(Q, P), spec(Q, N), spec(Q, N), spec(Q)],
        out_specs=[spec(Q, P), spec(N, P)],
        out_shape=[
            jax.ShapeDtypeStruct((BC, H, Q, P), xdt.dtype),
            jax.ShapeDtypeStruct((BC, H, N, P), jnp.float32),
        ],
        interpret=interpret,
    )(xdt, b, c, csum)
