"""Jit'd wrapper for the SSD intra-chunk kernel."""
from __future__ import annotations

import jax

from repro.kernels.ssd.kernel import ssd_chunk_pallas
from repro.kernels.ssd.ref import ssd_chunk_ref


def ssd_chunk(xdt, b, c, csum, *, use_pallas=False, interpret: bool = True):
    """xdt (BC,H,Q,P), b/c (BC,H,Q,N), csum (BC,H,Q) ->
    (y_intra (BC,H,Q,P), state (BC,H,N,P))."""
    if use_pallas:
        y, st = ssd_chunk_pallas(xdt, b, c, csum, interpret=interpret)
        return y, st
    return ssd_chunk_ref(xdt, b, c, csum)


ssd_chunk_jit = jax.jit(ssd_chunk, static_argnames=("use_pallas", "interpret"))
